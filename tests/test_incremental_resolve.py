"""Incremental per-occupancy re-solve: warm starts, proportional L2
splits, and the plan-miss failure paths.

Covers the PR-6 contract:

  * a ``plan_for`` miss warm-starts from the Hamming-nearest cached
    occupancy's tiling solutions (``PlanStore.nearest_solutions`` — a
    non-evicting sidecar, so LRU eviction of a plan never destroys the
    warm-start source) and never produces a plan worse than the
    compile-alone concat floor (property-tested over every occupancy);
  * churny traces (one tenant arriving/leaving) reuse neighbor
    solutions: every miss is warm, and a replay of the trace compiles
    nothing;
  * ``BackgroundCompiler`` no longer poisons an occupancy on the first
    raised compile: ``max_retries`` with exponential backoff rounds,
    then poisoning, then ``clear_failed()`` lifts it;
  * ``CompileRequest`` rejects an inverted lazy/foreground joint budget
    pair; ``PlanStore.stats()['re_misses']`` counts evictions that
    forced a re-compile; ``proportional_budgets`` splits the L2 by
    working set without starving a tenant.
"""

import pytest

from repro.core.deploy import (CompileRequest, DeploymentSession, PlanStore,
                               proportional_budgets)
from repro.core.tiling import solution_ws_bytes
from repro.serve.compiler_thread import BackgroundCompiler
from repro.serve.engine import MultiModelEngine
from repro.soc.testbed import dense_chain, two_acc_soc


def make_session(**kw) -> DeploymentSession:
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain("a", [64, 64, 64]),
              dense_chain("b", [48, 48, 48]),
              dense_chain("c", [32, 32, 32])]
    kw.setdefault("requested_tiles", 4)
    kw.setdefault("time_budget_s", 0.5)
    kw.setdefault("joint_time_budget_s", 0.5)
    kw.setdefault("lazy_joint_time_budget_s", 0.5)
    kw.setdefault("incremental_time_budget_s", 0.5)
    return DeploymentSession(CompileRequest(
        graphs=graphs, soc=soc, patterns=pats, **kw))


@pytest.fixture(scope="module")
def session():
    s = make_session()
    s.compile()
    return s


# ---------------------------------------------------------------------------
# Property: warm-started neighbor solves never lose to the floor
# ---------------------------------------------------------------------------


def all_occupancies(n):
    out = []
    for mask in range(1, 2 ** n):
        out.append([i for i in range(n) if mask & (1 << i)])
    return out


def test_warm_subset_never_worse_than_floor(session):
    """Every occupancy's plan — warm-started or not — beats or ties the
    compile-alone concat floor (zero negative-gain rounds), and every
    subset miss found a warm neighbor (the full house is always
    recorded, so a comparable superset always exists)."""
    n = len(session.request.graphs)
    for ids in all_occupancies(n):
        plan = session.plan_for(ids)
        floor = sum(session.singles[i].plan.makespan for i in ids)
        assert plan.makespan <= floor + 1e-6, \
            f"occupancy {ids}: {plan.makespan} above floor {floor}"
    assert all(e["warm"] for e in session.miss_events)
    assert session.incremental_hits == len(session.miss_events)
    stats = session.compile_latency_stats()
    assert stats["count"] == len(session.miss_events) > 0
    assert stats["cold"]["count"] == 0
    assert stats["p99_ms"] is not None


def test_proportional_split_never_ships_worse_than_equal(session):
    """Multi-tenant misses that arbitrated both splits recorded both
    makespans, and the shipped plan is the better of the two."""
    both = [e for e in session.miss_events
            if e["split"] is not None]
    for e in both:
        best = min(e["proportional_makespan"], e["equal_makespan"])
        assert e["makespan"] <= best + 1e-6


# ---------------------------------------------------------------------------
# Churny traces reuse neighbor solutions
# ---------------------------------------------------------------------------


def test_churny_trace_reuses_neighbor_solutions():
    """One tenant arrives/leaves per round: every miss warm-starts from a
    cached neighbor (solve-count assertion: incremental_hits == misses),
    and a replay of the trace compiles nothing new."""
    s = make_session()
    s.compile()
    trace = [(0, 1, 2), (1, 2), (0, 1, 2), (0, 2), (0,), (0, 1)]
    for ids in trace:
        s.plan_for(ids)
    misses = len(s.miss_events)
    assert misses == 4                    # the four non-full occupancies
    assert s.incremental_hits == misses   # all warm-started
    assert all(e["warm"] and e["neighbor"] is not None
               for e in s.miss_events)
    compiles = s.store.stats()["compiles"]
    for ids in trace:                     # replay: pure cache hits
        s.plan_for(ids)
    assert len(s.miss_events) == misses
    assert s.store.stats()["compiles"] == compiles


def test_nearest_solutions_prefers_nearest_superset(session):
    """Distance ranking: the occupancy itself (distance 0, post-eviction
    re-compiles) beats a superset at distance 1 beats the full house at
    distance 2; non-comparable occupancies are never returned."""
    store = PlanStore()
    store.seed_solutions([0, 1, 2], {0: "s0", 1: "s1", 2: "s2"})
    store.seed_solutions([0, 1], {0: "a0", 1: "a1"})
    occ, sols = store.nearest_solutions([0])
    assert occ == frozenset({0, 1})       # distance 1 superset
    assert sols == {0: "a0", 1: "a1"}
    occ, _ = store.nearest_solutions([0, 1])
    assert occ == frozenset({0, 1})       # exact key at distance 0
    occ, _ = store.nearest_solutions([1, 2])
    assert occ == frozenset({0, 1, 2})    # ({0,1} is not comparable)
    assert store.nearest_solutions([0]) is not None
    empty = PlanStore()
    assert empty.nearest_solutions([0]) is None


def test_sidecar_survives_plan_eviction():
    """LRU eviction of a plan never destroys the warm-start source: the
    solutions sidecar still answers for the evicted occupancy, and its
    re-compile warm-starts from its own previous solutions."""
    s = make_session(store_max_entries=1)
    s.compile()                           # full house is protected
    s.plan_for([0, 1])
    s.plan_for([1, 2])                    # evicts {0,1}
    assert frozenset({0, 1}) not in s.store
    assert s.store.solutions([0, 1]) is not None
    s.plan_for([0, 1])                    # re-compile after eviction
    last = s.miss_events[-1]
    assert last["occupancy"] == (0, 1)
    assert last["warm"] and last["neighbor"] == (0, 1)
    assert s.store.stats()["re_misses"] == 1


# ---------------------------------------------------------------------------
# re_misses: evictions that forced a re-compile
# ---------------------------------------------------------------------------


def test_re_misses_counts_thrash_once_per_eviction():
    store = PlanStore(max_entries=1)
    store.co_plan([0], lambda: "p0")
    store.co_plan([1], lambda: "p1")      # evicts {0}
    assert store.stats()["evictions"] == 1
    assert store.stats()["re_misses"] == 0
    store.co_plan([0], lambda: "p0b")     # the eviction forced this
    assert store.stats()["re_misses"] == 1
    store.peek([1], touch=True)           # second miss of same eviction
    assert store.stats()["re_misses"] == 2
    store.peek([1], touch=True)           # ... is counted only once
    assert store.stats()["re_misses"] == 2


def test_engine_report_surfaces_re_misses_and_latency(session):
    eng = MultiModelEngine(session.compile(), execute=False)
    eng.submit(0)
    eng.submit(1)
    eng.step()
    rep = eng.report()
    assert "re_misses" in rep["plan_store"]
    lat = rep["compile_latency"]
    assert lat["count"] == len(session.miss_events)
    assert set(lat) >= {"p50_ms", "p99_ms", "warm", "cold",
                        "incremental_hits"}


# ---------------------------------------------------------------------------
# Retry / poison lifecycle (satellite bugfix)
# ---------------------------------------------------------------------------


class FlakySession:
    """submit_compile raises ``fail_times`` times, then lands."""

    def __init__(self, fail_times):
        self.fail_times = fail_times
        self.calls = 0
        self.cached = set()

    def try_plan_for(self, key, touch=False):
        return "plan" if frozenset(key) in self.cached else None

    def submit_compile(self, key, source="background"):
        self.calls += 1
        if self.calls <= self.fail_times:
            raise RuntimeError("transient joint-CP timeout")
        self.cached.add(frozenset(key))
        return True


def test_transient_failure_retries_then_compiles():
    """One raised compile no longer poisons the occupancy: the next
    submit retries and lands the plan."""
    fake = FlakySession(fail_times=1)
    bg = BackgroundCompiler(fake, start=False, max_retries=2)
    assert bg.submit([0, 1])
    bg.run_pending()                      # raises once
    assert bg.stats()["failed_occupancies"] == 0
    assert bg.submit([0, 1])              # retry allowed next round
    bg.run_pending()
    assert bg.compiled == 1
    assert fake.try_plan_for([0, 1]) is not None
    assert bg.stats()["retries"] == 1
    assert bg.stats()["errors"] == 1


def test_retries_exhaust_then_poison_then_clear():
    """max_retries raised compiles with exponential backoff rounds, then
    the occupancy is poisoned; clear_failed() lifts the poison."""
    fake = FlakySession(fail_times=10)    # always fails (until cleared)
    bg = BackgroundCompiler(fake, start=False, max_retries=2,
                            backoff_rounds=1)
    assert bg.submit([0])                 # attempt 1
    bg.run_pending()
    assert bg.submit([0])                 # backoff 1 round: allowed
    bg.run_pending()                      # attempt 2
    assert not bg.submit([0])             # backoff 2 rounds: deferred
    assert bg.stats()["backoffs"] == 1
    assert bg.submit([0])                 # attempt 3 (= max_retries + 1)
    bg.run_pending()
    assert bg.stats()["failed_occupancies"] == 1
    assert not bg.submit([0])             # poisoned: dedupes forever
    assert bg.stats()["retries"] == 2
    assert bg.compiled == 0

    fake.fail_times = 0                   # operator fixed the condition
    assert bg.clear_failed() == 1
    assert bg.stats()["failed_occupancies"] == 0
    assert bg.submit([0])
    bg.run_pending()
    assert bg.compiled == 1


def test_success_resets_retry_state():
    fake = FlakySession(fail_times=1)
    bg = BackgroundCompiler(fake, start=False, max_retries=1)
    bg.submit([2])
    bg.run_pending()                      # fail once
    bg.submit([2])
    bg.run_pending()                      # lands
    assert bg.compiled == 1
    # a later failure of the SAME occupancy starts a fresh retry budget
    fake.cached.clear()
    fake.calls = 0
    fake.fail_times = 1
    bg.submit([2])
    bg.run_pending()                      # fails again — not poisoned
    assert bg.stats()["failed_occupancies"] == 0


# ---------------------------------------------------------------------------
# CompileRequest budget-pair validation (satellite bugfix)
# ---------------------------------------------------------------------------


def test_inverted_lazy_budget_pair_raises():
    soc, pats = two_acc_soc(64, 8.0)
    g = dense_chain("a", [32, 32])
    with pytest.raises(ValueError, match="lazy_joint_time_budget_s"):
        CompileRequest(graphs=[g], soc=soc, patterns=pats,
                       joint_time_budget_s=1.0,
                       lazy_joint_time_budget_s=2.0)
    # the <= 0 ablation sentinel ("joint budget already spent") still
    # constructs — joint_tilings clamps lazy/incremental budgets to it
    req = CompileRequest(graphs=[g], soc=soc, patterns=pats,
                         joint_time_budget_s=0.0)
    assert req.lazy_joint_time_budget_s > 0.0
    with pytest.raises(ValueError):
        CompileRequest(graphs=[g], soc=soc, patterns=pats,
                       incremental_time_budget_s=0.0)
    with pytest.raises(ValueError):
        CompileRequest(graphs=[g], soc=soc, patterns=pats,
                       l2_split="nope")


def test_zero_joint_budget_disables_incremental_solves_too():
    """The clamp: with the joint budget spent, a warm-started subset miss
    must not run a 1.5s incremental solve behind the foreground path's
    back — it falls back like everything else."""
    s = make_session(joint_time_budget_s=0.0, strategies=[
        "tile-centric", "all-or-nothing", "heft", "joint-cp"])
    s.compile()
    before = s.joint_solves
    s.plan_for([0, 1])
    assert s.joint_solves == before       # no joint solve ran
    assert s.joint_fallbacks > 0


# ---------------------------------------------------------------------------
# Proportional budgets
# ---------------------------------------------------------------------------


def test_proportional_budgets_units():
    assert proportional_budgets(1000, [3.0, 1.0]) == [719, 281]
    assert sum(proportional_budgets(999, [1.0, 2.0, 3.0])) == 999
    # degenerate weights fall back to the equal split
    assert proportional_budgets(1000, [0.0, 0.0]) == [500, 500]
    assert proportional_budgets(1000, [5.0]) == [1000]
    assert proportional_budgets(1000, []) == []
    # the min_frac floor protects a near-zero-weight tenant
    b = proportional_budgets(1024, [1e9, 1.0])
    assert b[1] >= int(512 * 0.125)
    assert all(x > 0 for x in b) and sum(b) == 1024


def test_solution_ws_bytes_positive(session):
    for i, cm in enumerate(session.singles):
        ws = solution_ws_bytes(session.request.graphs[i], cm.solution)
        assert ws > 0.0
