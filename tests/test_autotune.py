"""BlockSpec autotuner: VMEM feasibility, divisibility, and the selected
tiles actually run through the Pallas kernels (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune


@pytest.mark.parametrize("M,N,K", [(512, 512, 512), (4096, 1024, 8192),
                                   (256, 12288, 4096)])
def test_tune_matmul_valid(M, N, K):
    t = autotune.tune_matmul(M, N, K)
    assert M % t.block_m == 0 and N % t.block_n == 0 and K % t.block_k == 0
    assert t.vmem_bytes <= autotune.VMEM_BUDGET
    assert t.est_seconds > 0


def test_tuned_matmul_runs_and_matches():
    from repro.kernels.matmul.matmul import matmul_pallas
    from repro.kernels.matmul.ref import matmul_ref
    M, N, K = 256, 256, 512
    t = autotune.tune_matmul(M, N, K, itemsize=4)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (M, K))
    b = jax.random.normal(key, (K, N))
    got = matmul_pallas(a, b, block_m=t.block_m, block_n=t.block_n,
                        block_k=t.block_k, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                               atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("S,Dh", [(4096, 128), (32768, 128), (1024, 256)])
def test_tune_attention_valid(S, Dh):
    t = autotune.tune_flash_attention(S, Dh)
    assert S % t.block_q == 0 and S % t.block_k == 0
    assert t.vmem_bytes <= autotune.VMEM_BUDGET


def test_tuned_attention_runs_and_matches():
    from repro.kernels.flash_attention.flash_attention import \
        flash_attention_pallas
    from repro.kernels.flash_attention.ref import attention_ref
    S, Dh = 256, 64
    t = autotune.tune_flash_attention(S, Dh)
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, S, 4, Dh))
    k = jax.random.normal(key, (1, S, 2, Dh))
    v = jax.random.normal(key, (1, S, 2, Dh))
    got = flash_attention_pallas(q, k, v, causal=True,
                                 block_q=min(t.block_q, 128),
                                 block_k=min(t.block_k, 128),
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(attention_ref(q, k, v)),
                               atol=5e-5, rtol=5e-5)


def test_long_seq_choice_is_not_hbm_bound():
    """LOMA intuition: the tuner sizes q blocks so the KV re-stream never
    dominates — at long S the pick must sit on the compute roofline
    (within a tie-break the smallest VMEM such tile wins)."""
    S, Dh = 32768, 128
    t = autotune.tune_flash_attention(S, Dh)
    compute_bound = 4.0 * S * S * Dh / autotune.PEAK_FLOPS
    assert t.est_seconds <= compute_bound * 1.0 + 1e-12
    kv_restream = (2 * S * Dh * 2 * (S // t.block_q)
                   + S * Dh * 2) / autotune.HBM_BW
    assert kv_restream <= compute_bound + 1e-12
