"""Property tests over the rewrite stage: tile segments per op always cover
[0, T) exactly (the executable form of Eq. 1), across random tile requests
and modes (hypothesis)."""

import pytest
from _hypo import given, settings, st

from repro.core.rewrite import rewrite
from repro.core.tiling import optimize_tiling
from repro.models import edge
from repro.soc.carfield import carfield_patterns, carfield_soc

SOC = carfield_soc()
PATS = carfield_patterns()
MODELS = ["autoencoder", "ds_cnn", "resnet50_block"]


@settings(max_examples=12, deadline=None)
@given(model=st.sampled_from(MODELS),
       tiles=st.sampled_from([2, 4, 8, 16]),
       mode=st.sampled_from(["match", "matcha"]))
def test_segments_partition_exactly(model, tiles, mode):
    g = edge.ALL_MODELS[model]()
    sol = optimize_tiling(g, SOC, PATS, mode=mode, requested_tiles=tiles,
                          time_budget_s=1.0)
    tg = rewrite(g, SOC, sol)
    assert tg.repairs == 0
    for op in g.topo_ops():
        segs = []
        for sn in tg.supernodes:
            if op.name in sn.op_names:
                segs.append((sn.tile_lo, sn.tile_hi))
        segs.sort()
        T = sol.tiles_per_op[op.name]
        covered = []
        for lo, hi in segs:
            covered.extend(range(lo, hi))
        assert sorted(covered) == list(range(T)), (op.name, segs, T)


def test_helpers_only_for_partial_row_tiled():
    g = edge.resnet()
    sol = optimize_tiling(g, SOC, PATS, mode="matcha", requested_tiles=8,
                          time_budget_s=2.0)
    tg = rewrite(g, SOC, sol)
    names_with_helpers = {h.super_name for h in tg.helpers}
    for sn in tg.supernodes:
        if sn.name in names_with_helpers:
            assert not sn.full
