"""Checkpoint manager + fault supervisor: save/restore, crash markers,
restart-from-checkpoint, straggler policy."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.fault.supervisor import (RunReport, StepFailure, Supervisor,
                                    SupervisorConfig)


def _tree(x=0.0):
    return {"a": jnp.full((4, 3), x), "b": [jnp.full((2,), x + 1),
                                            jnp.zeros((), jnp.int32)]}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(3.5)
    mgr.save(7, t, blocking=True)
    assert mgr.latest_step() == 7
    got = mgr.restore(7, _tree())
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, _tree(float(s)))
    mgr.wait()
    assert mgr.finished_steps() == [3, 4]


def test_unfinished_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1.0), blocking=True)
    # simulate a crash mid-write: directory without DONE
    os.makedirs(tmp_path / "step_000002" / "data")
    assert mgr.latest_step() == 1


def test_supervisor_restarts_from_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    cfg = SupervisorConfig(total_steps=20, ckpt_every=5, max_restarts=3)
    sup = Supervisor(cfg, mgr, failure_schedule={12: StepFailure("boom")})
    trace = []

    def step_fn(state, step):
        trace.append(step)
        return {"a": state["a"] + 1.0,
                "b": [state["b"][0], state["b"][1] + 1]}

    report = sup.run(_tree(0.0), step_fn)
    assert report.restarts == 1
    assert report.steps_run == 20
    # one measured recovery latency per restart (failure -> restored)
    assert len(report.recovery_s) == 1 and report.recovery_s[0] >= 0.0
    # steps 11..12 re-executed after restoring step-10 checkpoint
    assert trace.count(12) == 2 or trace.count(11) == 2
    final = report.final_state
    assert int(final["b"][1]) == 20      # effective steps applied once each


def test_supervisor_straggler_detection(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    cfg = SupervisorConfig(total_steps=30, ckpt_every=100,
                           straggler_factor=2.5, straggler_patience=2)
    times = {k: 0.01 for k in range(30)}
    for k in (20, 21, 22):
        times[k] = 0.2                     # a slow replica appears
    mitigated = []
    sup = Supervisor(cfg, mgr, step_time_hook=lambda s: times[s],
                     on_straggler=lambda s: mitigated.append(s))
    report = sup.run(_tree(0.0), lambda st, i: st)
    assert len(report.stragglers) >= 2
    assert report.mitigations >= 1 and mitigated
