"""Property-based co-scheduler invariants on randomized small graphs x
random 2-3-device SoCs (via the _hypo shim):

  * no two nodes overlap on the same device / the DMA engine,
  * every predecessor finishes before a node starts,
  * shared-L2 occupancy never exceeds capacity (overlap-free packing),
  * each tenant's makespan >= that tenant's critical path.
"""

from _hypo import given, settings, st

from repro.core.memplan import validate_plan
from repro.core.patterns import chain, wildcard
from repro.core.rewrite import rewrite
from repro.core.schedule import (_upward_rank, build_dag, default_budgets,
                                 schedule_multi, validate_multi_schedule)
from repro.core.ir import Graph
from repro.core.tiling import optimize_tiling
from repro.soc.device import Device, MemoryLevel, SoC

KiB = 1024
WIDTHS = [8, 16, 32, 48, 64]


def _rand_soc(draw):
    n_acc = draw(st.integers(1, 2))           # host + 1..2 accelerators
    devices = {}
    devices["host"] = Device(
        name="host", alpha=2.0,
        l1=MemoryLevel("host_l1", 16 * KiB, 8.0),
        dma_bandwidth=8.0, is_host=True, copy_bandwidth=1.0)
    for j in range(n_acc):
        name = f"acc{j}"
        devices[name] = Device(
            name=name, alpha=0.4 + 0.4 * draw(st.integers(0, 2)),
            l1=MemoryLevel(f"{name}_l1", 32 * KiB, 16.0),
            dma_bandwidth=8.0)
    l2_size = draw(st.sampled_from([48 * KiB, 64 * KiB, 128 * KiB]))
    soc = SoC(name="randsoc", devices=devices,
              l2=MemoryLevel("l2", l2_size, 16.0),
              l3=MemoryLevel("l3", 16 * 1024 * KiB, 8.0),
              dma_l3_bandwidth=8.0, mailbox_latency=100.0, freq_mhz=50.0)
    pats = []
    for d in devices:
        eta = 0.3 + 0.1 * draw(st.integers(0, 4))
        pats.append(chain(d, f"{d}_dense", ["dense"], eta, 200.0))
        pats.append(chain(d, f"{d}_dense_relu", ["dense", "relu"],
                          eta, 200.0))
    pats.append(wildcard("host", eta=0.25, delta=100.0))
    return soc, pats


def _rand_graph(draw, idx: int) -> Graph:
    g = Graph(f"m{idx}")
    w0 = draw(st.sampled_from(WIDTHS))
    x = g.add_input("x", (1, w0), "float16")
    depth = draw(st.integers(2, 4))
    cin = w0
    for li in range(depth):
        cout = draw(st.sampled_from(WIDTHS))
        w = g.add_param(f"l{li}_w", (cin, cout), "float16")
        x = g.add_op("dense", [x, w], name=f"l{li}")
        if draw(st.integers(0, 1)):
            x = g.add_op("relu", [x], name=f"l{li}_relu")
        cin = cout
    g.mark_output(x)
    return g


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_coschedule_invariants(data):
    soc, pats = _rand_soc(data.draw)
    n_tenants = data.draw(st.integers(2, 3))
    tgs = []
    for i in range(n_tenants):
        g = _rand_graph(data.draw, i)
        sol = optimize_tiling(g, soc, pats, mode="matcha_nt",
                              requested_tiles=data.draw(
                                  st.sampled_from([2, 4])),
                              time_budget_s=0.5)
        tgs.append(rewrite(g, soc, sol))
    plan = schedule_multi(tgs, soc)

    # precedence + per-device / per-DMA mutual exclusion
    assert validate_multi_schedule(plan) == []

    # shared-L2 occupancy: overlap-free rectangles within capacity
    assert validate_plan(plan.memory) == []
    assert plan.memory.peak <= soc.l2.size

    # per-tenant makespan >= that tenant's critical path
    budgets = default_budgets(soc, n_tenants)
    for i, tg in enumerate(tgs):
        rank = _upward_rank(build_dag(tg, soc, budgets[i]))
        cp = max(rank.values(), default=0.0)
        assert plan.tenant_makespans[i] >= cp - 1e-6, (i, cp)

    # every tenant's every node is inside [0, makespan]
    for n in plan.nodes.values():
        assert n.start >= -1e-9
        assert n.end <= plan.makespan + 1e-6


def _dense_chain(name, widths):
    g = Graph(name)
    x = g.add_input("x", (1, widths[0]), "float16")
    cin = widths[0]
    for i, cout in enumerate(widths[1:]):
        w = g.add_param(f"l{i}_w", (cin, cout), "float16")
        x = g.add_op("dense", [x, w], name=f"l{i}")
        x = g.add_op("relu", [x], name=f"l{i}_r")
        cin = cout
    g.mark_output(x)
    return g


def test_contention_eviction_packing_stays_valid():
    """Regression: with an L2 so small that tenants must evict each other,
    the shared packing must stay overlap-free.  (Double-buffered DMA lets
    reservation times run backwards relative to allocator order; the
    mem_clock clamp in _MultiSimState keeps the rectangles consistent.)"""
    host = Device("host", 2.0, MemoryLevel("hl1", 8 * KiB, 8.0), 8.0,
                  is_host=True, copy_bandwidth=1.0)
    acc = Device("acc0", 0.5, MemoryLevel("al1", 16 * KiB, 16.0), 8.0)
    pats = [chain("host", "h_d", ["dense"], 0.4, 100.0),
            chain("acc0", "a_d", ["dense"], 0.5, 200.0),
            wildcard("host", eta=0.25, delta=100.0)]
    soc = SoC("tiny", {"host": host, "acc0": acc},
              l2=MemoryLevel("l2", 6 * KiB, 16.0),
              l3=MemoryLevel("l3", 16 * 1024 * KiB, 8.0),
              dma_l3_bandwidth=8.0, mailbox_latency=100.0, freq_mhz=50.0)
    gs = [_dense_chain("a", [32, 32, 32, 32]),
          _dense_chain("b", [32, 32, 32, 32]),
          _dense_chain("c", [16, 32, 16, 32])]
    tgs = []
    for g in gs:
        sol = optimize_tiling(g, soc, pats, mode="matcha_nt",
                              requested_tiles=2, time_budget_s=0.5)
        tgs.append(rewrite(g, soc, sol))
    plan = schedule_multi(tgs, soc)
    assert validate_multi_schedule(plan) == []
    assert validate_plan(plan.memory) == []
    assert plan.memory.peak <= soc.l2.size
    evictions = [s for s in plan.memory.swaps if s.direction == "out"]
    assert evictions, "scenario must actually exercise eviction traffic"
