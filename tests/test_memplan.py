"""Memory planner invariants (property-based 2-D packing checks)."""

from _hypo import given, settings, st

from repro.core.memplan import (Allocation, L2Allocator, MemoryPlan,
                                validate_plan)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 4096),      # size
                          st.integers(0, 50),        # alloc time
                          st.integers(1, 30)),       # lifetime
                min_size=1, max_size=40))
def test_allocator_never_overlaps(reqs):
    """Drive the first-fit allocator through arbitrary alloc/free traffic;
    the resulting rectangle set must be overlap-free and in-range."""
    alloc = L2Allocator(capacity=16 * 1024)
    live = []
    t = 0.0
    for i, (size, at, life) in enumerate(reqs):
        t = max(t, float(at))
        # free everything that expired
        for name, t_end in list(live):
            if t_end <= t:
                alloc.free(name, t_end)
                live.remove((name, t_end))
        a = alloc.alloc(f"t{i}", size, t)
        if a is not None:
            live.append((f"t{i}", t + life))
    for name, t_end in live:
        alloc.free(name, t_end)
    plan = MemoryPlan(capacity=alloc.capacity, allocations=alloc.history,
                      swaps=[], peak=alloc.peak)
    assert validate_plan(plan) == []
    assert alloc.used() == 0


def test_fits_all_matches_reality():
    alloc = L2Allocator(capacity=1024)
    a = alloc.alloc("a", 512, 0.0)
    assert a is not None
    segs = alloc.segments_assuming_freed([])
    assert L2Allocator.fits_all(segs, [448])
    assert not L2Allocator.fits_all(segs, [640])
    # hypothetically freeing "a" makes 640 fit
    segs2 = alloc.segments_assuming_freed(["a"])
    assert L2Allocator.fits_all(segs2, [640, 256])


def test_free_list_merging():
    alloc = L2Allocator(capacity=1024)
    names = []
    for i in range(4):
        alloc.alloc(f"x{i}", 256, 0.0)
        names.append(f"x{i}")
    for n in names:
        alloc.free(n, 1.0)
    assert alloc._free == [(0, 1024)]
