"""The compiler's correctness contract: tile-by-tile plan execution in JAX
matches direct whole-graph evaluation for every benchmark model x mode."""

import pytest

from repro.core.api import compile_model
from repro.core.runtime import plan_matches_oracle
from repro.models import edge
from repro.soc.carfield import carfield_patterns, carfield_soc

# excluded from the fast CI lane (-m "not slow")
pytestmark = pytest.mark.slow

SOC = carfield_soc()
PATS = carfield_patterns()

CASES = [
    ("autoencoder", "matcha"), ("autoencoder", "match"),
    ("ds_cnn", "matcha"), ("mobilenet", "matcha"),
    ("resnet", "matcha"), ("resnet", "matcha_nt"), ("resnet", "tvm"),
    ("resnet50_block", "matcha"),
    ("resnext50_block", "matcha"),
    ("transformer_block", "matcha"),
]


@pytest.mark.parametrize("model,mode", CASES)
def test_plan_matches_oracle(model, mode):
    cm = compile_model(edge.ALL_MODELS[model](), SOC, PATS, mode=mode,
                       time_budget_s=2.0)
    assert plan_matches_oracle(cm.plan)
