"""PR-9 surface: decomposed joint CP solve, the worker-pool background
compiler with its occupancy-lattice prefetcher, ``PlanStore`` warm-start
sidecar semantics under concurrent seed/evict, and per-solve telemetry.

Concurrency tests are deterministic: thread starts are synchronized with
``threading.Barrier`` (a timeout on the barrier is the failure signal),
never with sleeps.  The pool test's barrier has one party per worker, so
it *proves* two workers were mid-compile simultaneously — a single-
threaded pool would deadlock the barrier and time out.
"""

import threading
from types import SimpleNamespace

import pytest

from repro.core.decompose import cluster_by_affinity, solve_decomposed
from repro.core.deploy import CompileRequest, DeploymentSession, PlanStore
from repro.core.tiling import TilingSolution
from repro.serve.compiler_thread import BackgroundCompiler
from repro.serve.engine import MultiModelEngine
from repro.soc.testbed import (dense_chain, gelu_chain, hetero_setup,
                               two_acc_soc)


def sol(objective: float = 1.0) -> TilingSolution:
    """A minimal stand-in solution for sidecar bookkeeping tests."""
    return TilingSolution(mode="matcha", assignments=[], tiles_per_op={},
                          objective=objective, optimal=True,
                          solver_nodes=0, wall_s=0.0)


class StubSession:
    """Duck-typed ``DeploymentSession`` for compiler unit tests: records
    every ``submit_compile`` call (occupancy, source) in arrival order
    and lands a sentinel plan, optionally rendezvousing on a barrier
    first so tests can prove worker concurrency."""

    def __init__(self, n: int = 4, max_workers: int = 1,
                 barrier: "threading.Barrier | None" = None) -> None:
        self.request = SimpleNamespace(graphs=[None] * n,
                                       max_workers=max_workers)
        self._plans = {}
        self._mu = threading.Lock()
        self.calls = []
        self.barrier = barrier

    def try_plan_for(self, active):
        with self._mu:
            return self._plans.get(frozenset(active))

    def submit_compile(self, active, joint_budget_s=None,
                       source="background"):
        key = frozenset(active)
        if self.barrier is not None:
            self.barrier.wait(timeout=10.0)
        with self._mu:
            self.calls.append((tuple(sorted(key)), source))
            if key in self._plans:
                return False
            self._plans[key] = object()
            return True


# ---------------------------------------------------------------------------
# PlanStore: nearest_solutions tie-breaking
# ---------------------------------------------------------------------------


def test_nearest_solutions_exact_key_wins_at_distance_zero():
    st = PlanStore()
    st.seed_solutions([0], {0: sol(10.0)})
    st.seed_solutions([0, 1], {0: sol(20.0), 1: sol(21.0)})
    occ, sols = st.nearest_solutions([0, 1])
    assert occ == frozenset({0, 1})
    assert sols[0].objective == 20.0 and set(sols) == {0, 1}


def test_nearest_solutions_superset_beats_subset_on_distance_tie():
    st = PlanStore()
    st.seed_solutions([0], {0: sol()})            # subset, distance 1
    st.seed_solutions([0, 1, 2], {i: sol() for i in range(3)})  # superset, 1
    occ, _ = st.nearest_solutions([0, 1])
    assert occ == frozenset({0, 1, 2})


def test_nearest_solutions_canonical_order_breaks_remaining_tie():
    st = PlanStore()
    st.seed_solutions([1, 2], {1: sol(), 2: sol()})
    st.seed_solutions([0, 1], {0: sol(), 1: sol()})
    # both are distance-1 supersets of {1}: canonical occupancy order
    # ({0, 1} < {1, 2}) decides, independent of insertion order
    occ, _ = st.nearest_solutions([1])
    assert occ == frozenset({0, 1})


def test_nearest_solutions_ignores_incomparable_occupancies():
    st = PlanStore()
    st.seed_solutions([0, 1], {0: sol(), 1: sol()})
    assert st.nearest_solutions([2]) is None      # disjoint
    assert st.nearest_solutions([1, 2]) is None   # overlapping, neither way


# ---------------------------------------------------------------------------
# PlanStore: sidecar under concurrent seed/evict
# ---------------------------------------------------------------------------


def test_sidecar_survives_concurrent_seed_and_evict():
    """Many threads seed plans + solutions into a 2-entry store: the
    bounded plan map must evict, the sidecar must not lose a single
    occupancy, and every occupancy must warm-start itself (distance 0)
    regardless of interleaving."""
    st = PlanStore(max_entries=2)
    n_threads, per_thread = 4, 6
    occs = [[t * per_thread + k, t * per_thread + k + 1]
            for t in range(n_threads) for k in range(per_thread)]
    gate = threading.Barrier(n_threads)

    def work(t: int) -> None:
        gate.wait(timeout=10.0)
        for occ in occs[t * per_thread:(t + 1) * per_thread]:
            st.seed(occ, object())
            st.seed_solutions(occ, {i: sol(float(i)) for i in occ})

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10.0)
    assert not any(t.is_alive() for t in threads)

    stats = st.stats()
    assert stats["evictions"] >= len(occs) - st.max_entries
    assert stats["co_plans"] <= st.max_entries
    assert stats["solution_seeds"] == len(occs)   # sidecar never evicts
    for occ in occs:
        got = st.solutions(occ)
        assert got is not None and set(got) == set(occ)
        near = st.nearest_solutions(occ)
        assert near is not None and near[0] == frozenset(occ)


# ---------------------------------------------------------------------------
# BackgroundCompiler: pool hardening
# ---------------------------------------------------------------------------


def test_max_workers_validation():
    with pytest.raises(ValueError):
        BackgroundCompiler(StubSession(), start=False, max_workers=0)
    # defaults from the session's CompileRequest knob
    bg = BackgroundCompiler(StubSession(max_workers=3), start=False)
    assert bg.max_workers == 3


def test_compile_request_knob_validation():
    soc, pats = two_acc_soc(64, 8.0)
    g = [dense_chain("a", [32, 32])]
    base = dict(graphs=g, soc=soc, patterns=pats)
    for bad in (dict(max_workers=0), dict(decompose="sometimes"),
                dict(decompose_min_tenants=1),
                dict(decompose_cut_rounds=-1),
                dict(decompose_max_cluster=0)):
        with pytest.raises(ValueError):
            CompileRequest(**base, **bad)


def test_exactly_once_under_concurrent_submits():
    """Eight threads race to submit the same occupancy: exactly one
    submit wins, exactly one compile runs."""
    stub = StubSession(n=4)
    bg = BackgroundCompiler(stub, start=False)
    n_threads = 8
    gate = threading.Barrier(n_threads)
    wins = []

    def racer() -> None:
        gate.wait(timeout=10.0)
        wins.append(bg.submit([0, 1]))

    threads = [threading.Thread(target=racer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10.0)
    assert sum(wins) == 1 and len(wins) == n_threads
    assert bg.duplicates == n_threads - 1
    assert bg.run_pending() == 1
    assert stub.calls == [((0, 1), "background")]
    assert bg.compiled == 1 and bg.pending == 0


def test_pool_runs_workers_concurrently_exactly_once():
    """Two queued occupancies, two workers, a two-party barrier inside
    the stub compile: the barrier only releases if both workers are
    mid-compile simultaneously.  Each occupancy compiles exactly once
    fleet-wide through the shared queued/in-flight sets."""
    rendezvous = threading.Barrier(2)
    stub = StubSession(n=6, max_workers=2, barrier=rendezvous)
    bg = BackgroundCompiler(stub, start=False, max_workers=2)
    assert bg.submit([0, 1]) and bg.submit([2, 3])
    assert bg.pending == 2
    bg.start()
    assert bg.drain(timeout_s=15.0)
    bg.stop(timeout_s=10.0)
    assert not bg.running
    assert bg.compiled == 2 and bg.pending == 0
    assert sorted(k for k, _ in stub.calls) == [(0, 1), (2, 3)]


def test_reactive_miss_outranks_queued_prefetch():
    stub = StubSession(n=4)
    bg = BackgroundCompiler(stub, start=False)
    assert bg.submit([0, 1], source="prefetch", priority=0.5)
    assert bg.submit([2], source="background", priority=0.0)
    assert bg.run_pending() == 2
    # the later-enqueued reactive miss compiled first
    assert stub.calls == [((2,), "background"), ((0, 1), "prefetch")]
    assert bg.prefetch_submitted == 1 and bg.submitted == 1
    assert bg.prefetch_compiled == 1 and bg.compiled == 2


# ---------------------------------------------------------------------------
# BackgroundCompiler: occupancy-lattice prefetcher
# ---------------------------------------------------------------------------


def test_prefetch_off_by_default():
    bg = BackgroundCompiler(StubSession(), start=False)
    assert bg.observe([0, 1]) == 0
    assert bg.pending == 0 and bg.stats()["prefetch"] is False


def test_observe_prefetches_hamming_neighbors():
    stub = StubSession(n=3)
    bg = BackgroundCompiler(stub, start=False, prefetch=True)
    got = bg.observe([0, 1])
    # neighbors of {0,1}: add -> {0,1,2} (full house, excluded),
    # remove -> {0} and {1}
    assert got == 2 and bg.prefetch_submitted == 2
    assert bg.run_pending() == 2
    assert sorted(stub.calls) == [((0,), "prefetch"), ((1,), "prefetch")]
    assert bg.prefetch_compiled == 2
    # now cached: a re-observation prefetches nothing new
    assert bg.observe([0, 1]) == 0


def test_prefetch_hint_registers_standing_candidates():
    stub = StubSession(n=5)
    bg = BackgroundCompiler(stub, start=False, prefetch=True)
    bg.prefetch_hint([[0, 2], [1, 3]], weight=5.0)
    assert bg.stats()["prefetch_hints"] == 2
    assert bg.prefetch_now() == 2
    assert bg.run_pending() == 2
    assert sorted(k for k, s in stub.calls if s == "prefetch") == \
        [(0, 2), (1, 3)]


def test_recent_window_bounds_anchor_set():
    bg = BackgroundCompiler(StubSession(n=8), start=False,
                            recent_window=2)
    for occ in ([0], [1], [2]):
        bg.observe(occ)
    with bg._lock:
        assert list(bg._recent) == [frozenset({1}), frozenset({2})]


# ---------------------------------------------------------------------------
# Decomposed joint solve
# ---------------------------------------------------------------------------


def test_affinity_clustering_splits_hetero_mix():
    soc, pats, graphs = hetero_setup(4)
    clusters = cluster_by_affinity(graphs, soc, pats, 4)
    assert [(c.device, c.tenants) for c in clusters] == \
        [("dsp", [1, 3]), ("npu", [0, 2])]
    # split budgets cover the shared L2 exactly
    from repro.core.decompose import _split_l2
    _split_l2(clusters, float(soc.l2.size),
              [c.ws_bytes for c in clusters])
    assert sum(c.l2_budget for c in clusters) == pytest.approx(
        float(soc.l2.size))


def test_max_cluster_size_splits_oversized_clusters():
    """An 8-tenant mix (4 per device) with ``max_cluster_size=2`` splits
    each device cluster into balanced contiguous sub-clusters — every
    tenant covered exactly once, per-device membership unchanged."""
    soc, pats, graphs = hetero_setup(8)
    capped = cluster_by_affinity(graphs, soc, pats, 4, max_cluster_size=2)
    assert [(c.device, c.tenants) for c in capped] == \
        [("dsp", [1, 3]), ("dsp", [5, 7]), ("npu", [0, 2]), ("npu", [4, 6])]
    # uncapped totals are conserved across the split
    flat = cluster_by_affinity(graphs, soc, pats, 4)
    for dev in ("dsp", "npu"):
        whole = next(c for c in flat if c.device == dev)
        parts = [c for c in capped if c.device == dev]
        assert sum(c.ws_bytes for c in parts) == pytest.approx(
            whole.ws_bytes)
        assert sum(c.var_weight for c in parts) == pytest.approx(
            whole.var_weight)
    # homogeneous degeneracy is judged per *device*: a single-device mix
    # stays monolithic even when the cap would chop it up
    soc2, pats2 = two_acc_soc(64, 8.0)
    graphs2 = [dense_chain(f"t{i}", [48, 48, 48]) for i in range(4)]
    assert solve_decomposed(graphs2, soc2, pats2, requested_tiles=4,
                            time_budget_s=0.5, max_cluster_size=2) is None


def test_homogeneous_mix_degenerates_to_none():
    """Every tenant on ``two_acc_soc`` shares a dominant device, so
    decomposition has nothing to split and reports the fallback."""
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain(f"t{i}", [48, 48, 48]) for i in range(3)]
    assert solve_decomposed(graphs, soc, pats, requested_tiles=4,
                            time_budget_s=0.5) is None


def test_solve_decomposed_covers_all_tenants():
    soc, pats, graphs = hetero_setup(4)
    res = solve_decomposed(graphs, soc, pats, requested_tiles=4,
                           time_budget_s=1.0)
    assert res is not None
    assert len(res.solutions) == len(graphs)
    assert all(s.assignments for s in res.solutions)
    st = res.stats()
    assert st["clusters"] == 2 and st["cluster_sizes"] == [2, 2]


def hetero_session(decompose: str = "on", **kw) -> DeploymentSession:
    soc, pats, graphs = hetero_setup(4)
    return DeploymentSession(CompileRequest(
        graphs=graphs, soc=soc, patterns=pats, requested_tiles=4,
        time_budget_s=0.5, joint_time_budget_s=1.0,
        lazy_joint_time_budget_s=0.5,
        decompose=decompose, decompose_cut_rounds=0, **kw))


def test_session_decompose_gating():
    off = hetero_session("off")
    assert off.decomposed_tilings([0, 1, 2, 3]) is None
    assert off.decomposed_fallbacks == 0          # disabled, not a fallback
    auto = hetero_session("auto")                 # default min_tenants = 6
    assert auto.decomposed_tilings([0, 1, 2, 3]) is None
    assert auto.decomposed_solves == 0


def test_session_decomposed_tilings_and_telemetry():
    sess = hetero_session("on")
    tgs = sess.decomposed_tilings([0, 1, 2, 3])
    assert tgs is not None and len(tgs) == 4
    assert sess.decomposed_solves == 1 and sess.decomposed_fallbacks == 0
    assert sess.decomposed_stats["clusters"] == 2
    ss = sess.solver_stats()
    assert ss["decomposed_solves"] == 1
    assert ss["by_context"]["decomposed"]["solves"] == 2  # one per cluster
    assert ss["nodes"] >= 0 and ss["wall_s"] > 0.0
    assert sum(ss["incumbent_source"].values()) == ss["solves"]


# ---------------------------------------------------------------------------
# Solver telemetry + per-source compile latency through the engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_session() -> DeploymentSession:
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain("a", [48, 48, 48]),
              dense_chain("b", [32, 32, 32]),
              dense_chain("c", [32, 32])]
    s = DeploymentSession(CompileRequest(
        graphs=graphs, soc=soc, patterns=pats,
        requested_tiles=4, time_budget_s=0.5))
    s.compile()
    return s


def test_engine_report_exposes_solver_stats(small_session):
    mc = small_session.compile()
    eng = MultiModelEngine(mc, execute=False)
    rep = eng.report()
    assert rep["solver"] is not None
    assert rep["solver"]["solves"] >= len(mc.graphs)
    assert "single" in rep["solver"]["by_context"]


def test_compile_latency_split_by_source(small_session):
    sess = small_session
    assert sess.submit_compile([0, 1], source="prefetch")
    stats = sess.compile_latency_stats()
    for src in ("foreground", "background", "prefetch"):
        assert src in stats
    assert stats["prefetch"]["count"] >= 1
    with pytest.raises(ValueError):
        sess.submit_compile([0, 2], source="speculative")


def test_submit_compile_rejects_bad_source(small_session):
    with pytest.raises(ValueError):
        small_session.submit_compile([1, 2], source="foreground")
