"""Shape buckets end to end: the key vocabulary, the composite-keyed
PlanStore, the session's bucketed plan lattice, the engine's mixed
prefill/decode rounds, and the lattice prefetcher's decode transition.

Also home to two satellite regression tests of the bugfix PR that
introduced buckets:

  * ``proportional_budgets`` may never sum past ``l2_size`` (a one-byte
    overshoot makes the joint CP's shared-L2 constraint infeasible) —
    property-tested over random weight vectors;
  * the PlanStore's composite (occupancy x bucket-vector) keys must
    keep the LRU honest: protected entries survive any pressure, the
    solutions sidecar answers distance-0 self-matches after eviction,
    and ``re_misses`` counts thrash per composite key, not per
    occupancy.
"""

import random

import pytest

from repro.core.shapes import (PlanKey, ShapeBucketSpec, describe_key,
                               key_distance, key_sort, make_plan_key,
                               pow2_buckets, remap_key)

MAX_SEQ = 32


# ---------------------------------------------------------------------------
# vocabulary: specs and keys
# ---------------------------------------------------------------------------


def _spec(lo=1, hi=MAX_SEQ, default=None):
    return ShapeBucketSpec(buckets=pow2_buckets(lo, hi),
                           make_graph=lambda s: None, default=default)


def test_bucket_spec_validation_and_rounding():
    spec = _spec()
    assert spec.buckets == (1, 2, 4, 8, 16, 32)
    assert spec.default == 32                       # prefill-heaviest
    assert spec.bucket_for(1) == 1                  # decode
    assert spec.bucket_for(3) == 4                  # round up
    assert spec.bucket_for(32) == 32
    assert spec.bucket_for(1000) == 32              # clamped
    assert spec.neighbors(1) == (2,)
    assert spec.neighbors(8) == (4, 16)
    assert spec.neighbors(32) == (16,)
    with pytest.raises(ValueError):
        spec.bucket_for(0)
    with pytest.raises(ValueError):
        spec.neighbors(3)                           # not a bucket
    with pytest.raises(ValueError):
        ShapeBucketSpec(buckets=(4, 2), make_graph=lambda s: None)
    with pytest.raises(ValueError):
        ShapeBucketSpec(buckets=(3,), make_graph=lambda s: None)
    with pytest.raises(ValueError):
        ShapeBucketSpec(buckets=(2, 4), make_graph=lambda s: None,
                        default=8)


def test_plan_key_canonicalization():
    # all-default collapses to the bare frozenset — bitwise the
    # pre-shape key, so fixed-shape stores never see a PlanKey
    assert make_plan_key([0, 1]) == frozenset({0, 1})
    assert make_plan_key([1, 0], {}) == frozenset({0, 1})
    k = make_plan_key([0, 1], {1: 4})
    assert isinstance(k, PlanKey)
    assert k.occupancy == frozenset({0, 1})
    assert k.bucket_of(1) == 4 and k.bucket_of(0) is None
    # PlanKey never collides with the bare key at the same occupancy
    assert k != frozenset({0, 1})
    assert hash(k) != hash(frozenset({0, 1})) or k != frozenset({0, 1})
    with pytest.raises(ValueError):
        PlanKey(frozenset({0, 1}), ())              # bucket-less
    with pytest.raises(ValueError):
        make_plan_key([0], {1: 4})                  # tenant not active
    with pytest.raises(ValueError):
        make_plan_key([0, 1], {1: 0})               # bucket < 1


def test_key_distance_and_order_on_the_product_lattice():
    bare = make_plan_key([0, 1])
    dec = make_plan_key([0, 1], {1: 1})
    pre = make_plan_key([0, 1], {1: 4})
    solo = make_plan_key([1], {1: 1})
    assert key_distance(bare, bare) == 0
    assert key_distance(dec, dec) == 0
    assert key_distance(bare, dec) == 1             # one bucket move
    assert key_distance(dec, pre) == 1              # ladder rung
    assert key_distance(dec, solo) == 1             # occupancy leave
    assert key_distance(bare, solo) == 2            # leave + bucket
    # deterministic total order: bare sorts before bucketed at the
    # same occupancy, smaller occupancies first
    keys = sorted([pre, bare, solo, dec], key=key_sort)
    assert keys == [solo, bare, dec, pre]
    # remap under a tenant re-indexing keeps the bucket vector
    rm = remap_key(dec, {0: 5, 1: 3})
    assert rm == make_plan_key([3, 5], {3: 1})
    # bare keys describe exactly like the pre-shape occupancy string
    assert describe_key(bare) == str(sorted({0, 1}))
    assert "t1:1" in describe_key(dec)


def test_proportional_budgets_never_overshoot_l2():
    """Satellite: floor + proportional share + remainder must sum to at
    most ``l2_size`` for ANY weights — the old rescale could round a ulp
    high and push the joint CP infeasible."""
    from repro.core.deploy import proportional_budgets
    rng = random.Random(7)
    for trial in range(500):
        n = rng.randint(1, 8)
        l2 = rng.choice([64, 1024, 65536, 2 ** 20, 7 * 11 * 13])
        kind = trial % 5
        if kind == 0:
            weights = [rng.random() for _ in range(n)]
        elif kind == 1:
            weights = [rng.random() * 1e9 for _ in range(n)]
        elif kind == 2:
            weights = [0.0] * n                     # degenerate: equal
        elif kind == 3:
            weights = [rng.choice([0.0, 1e-12, 1.0]) for _ in range(n)]
        else:
            weights = [rng.random() * rng.choice([1e-9, 1.0, 1e6])
                       for _ in range(n)]
        budgets = proportional_budgets(l2, weights)
        assert len(budgets) == n
        assert sum(budgets) <= l2, (l2, weights, budgets)
        assert all(b >= 1 for b in budgets), (l2, weights, budgets)
        # non-degenerate splits use the whole budget
        if n > 1 and sum(w for w in weights if w > 0.0) > 0.0:
            equal = l2 // n
            floor = max(int(equal * 0.125), 1)
            if l2 - n * floor >= 0:
                assert sum(budgets) == l2, (l2, weights, budgets)
                assert all(b >= floor for b in budgets)


# ---------------------------------------------------------------------------
# PlanStore: composite keys
# ---------------------------------------------------------------------------


def _fake_plan():
    class P:                                        # identity is enough
        pass
    return P()


def test_store_composite_keys_lru_and_sidecar():
    """Satellite: the (occupancy x bucket) key space is much larger than
    the occupancy space, so LRU pressure arrives sooner — protected
    entries must still never evict, the sidecar must self-match at
    distance 0 after its plan is evicted, and re_misses must count per
    composite key."""
    from repro.core.deploy import PlanStore
    store = PlanStore(max_entries=4)
    full = frozenset({0, 1})
    store.protect(full)
    store.seed(full, _fake_plan())
    # flood the store with bucketed lattice points at ONE occupancy
    keys = [make_plan_key([0, 1], {1: b}) for b in (1, 2, 4, 8, 16)]
    for k in keys:
        store.seed(k, _fake_plan())
        store.seed_solutions(k, {0: f"sol0@{k}", 1: f"sol1@{k}"})
    # bound respected, protected bare key survived the flood
    assert store.stats()["co_plans"] <= 4
    assert store.peek(full) is not None
    assert store.stats()["evictions"] >= 2
    # the evicted lattice points are gone; the freshest are present
    assert store.peek(keys[0]) is None
    assert store.peek(keys[-1]) is not None
    # sidecar never evicts: the evicted key's own solutions still answer
    # at distance 0 (an evicted plan's own solutions are the best warm
    # start for its re-compile)
    near = store.nearest_solutions(keys[0])
    assert near is not None
    nkey, sols = near
    assert nkey == keys[0]
    assert key_distance(nkey, keys[0]) == 0
    assert sols[1] == f"sol1@{keys[0]}"
    # re-miss accounting is per composite key: touching the evicted
    # decode point counts exactly one re-miss; a different bucket at the
    # same occupancy does not double-count it
    before = store.stats()["re_misses"]
    assert store.peek(keys[0], touch=True) is None
    assert store.stats()["re_misses"] == before + 1
    assert store.peek(keys[0], touch=True) is None
    assert store.stats()["re_misses"] == before + 1     # counted once
    # bare vs bucketed keys never collide
    store.seed(make_plan_key([2, 3]), _fake_plan())
    assert store.peek(make_plan_key([2, 3], {3: 2})) is None


def test_store_protected_entries_survive_any_pressure():
    from repro.core.deploy import PlanStore
    store = PlanStore(max_entries=2)
    protected = [frozenset({0, 1}), make_plan_key([0, 1], {1: 1})]
    for k in protected:
        store.protect(k)
        store.seed(k, _fake_plan())
    for b in (2, 4, 8, 16, 32):
        store.seed(make_plan_key([0, 1], {1: b}), _fake_plan())
    for k in protected:
        assert store.peek(k) is not None, k
    assert store.stats()["evictions"] >= 4


# ---------------------------------------------------------------------------
# session + engine fixtures (compiled once per module — CP solves)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lm_session():
    from repro.core.deploy import CompileRequest, DeploymentSession
    from repro.models.lm_graphs import lm_tenant
    from repro.soc.testbed import dense_chain, two_acc_soc
    soc, pats = two_acc_soc(512, 8.0)
    lm_graph, lm_spec = lm_tenant("rwkv6", max_seq=MAX_SEQ, d=64, ffn=128)
    session = DeploymentSession(CompileRequest(
        graphs=[dense_chain("vision", [64, 64, 64]), lm_graph],
        soc=soc, patterns=pats, requested_tiles=4, time_budget_s=0.5,
        joint_time_budget_s=1.0, lazy_joint_time_budget_s=0.5,
        incremental_time_budget_s=0.5, shape_buckets={1: lm_spec}))
    session.compile()
    return session


def test_session_bucketed_plan_lattice(lm_session):
    s = lm_session
    # plan_key canonicalizes: default bucket drops out, PlanKey passes
    # through (and refuses a second shapes argument)
    assert s.plan_key([0, 1]) == frozenset({0, 1})
    assert s.plan_key([0, 1], {1: MAX_SEQ}) == frozenset({0, 1})
    k = s.plan_key([0, 1], {1: 1})
    assert isinstance(k, PlanKey)
    assert s.plan_key(k) is k
    with pytest.raises(ValueError):
        s.plan_key(k, {1: 2})
    with pytest.raises(ValueError):
        s.plan_key([0, 1], {0: 4})          # vision has no bucket spec
    with pytest.raises(ValueError):
        s.plan_key([0, 1], {1: 3})          # not a bucket of the spec

    # the decode lattice point compiles to a distinct, cheaper plan
    full = s.plan_for([0, 1])
    dec = s.plan_for([0, 1], shapes={1: 1})
    assert dec is not full
    assert dec.makespan < full.makespan
    # cached: same object on re-query, also via the PlanKey spelling
    assert s.plan_for([0, 1], shapes={1: 1}) is dec
    assert s.plan_for(k) is dec
    assert s.try_plan_for([0, 1], shapes={1: 1}) is dec

    # bucket singles price the floor at the bucket, not the prefill graph
    dec_single = s.bucket_single(1, 1)
    pre_single = s.bucket_single(1, MAX_SEQ)
    assert pre_single is s.compile().singles[1]     # default identity
    assert dec_single.plan.makespan < pre_single.plan.makespan

    # the decode co-round beats the sequential (compile-alone) floor —
    # the ISSUE's headline acceptance property
    floor = (s.compile().singles[0].plan.makespan
             + dec_single.plan.makespan)
    assert dec.makespan < floor


def test_session_bucketed_plans_are_analyzer_clean(lm_session):
    s = lm_session
    s.plan_for([0, 1], shapes={1: 1})
    s.plan_for([1], shapes={1: 2})
    stats = s.analysis_stats()
    assert stats["errors"] == 0


# ---------------------------------------------------------------------------
# engine: mixed prefill/decode rounds
# ---------------------------------------------------------------------------


def _engine(lm_session, prefetch=True, **kw):
    from repro.serve.compiler_thread import BackgroundCompiler
    from repro.serve.engine import MultiModelEngine
    compiler = BackgroundCompiler(lm_session, start=False,
                                  prefetch=prefetch)
    eng = MultiModelEngine(lm_session.compile(), execute=False,
                           async_compile=compiler, **kw)
    return eng, compiler


def test_engine_buckets_requests_and_prices_floors(lm_session):
    eng, _ = _engine(lm_session)
    rid_pre = eng.submit(1, seq_len=30)             # rounds up to 32
    rid_dec = eng.submit(1, seq_len=1)
    pre_req = eng.queues[1][0]
    dec_req = eng.queues[1][1]
    assert pre_req.rid == rid_pre and pre_req.bucket == MAX_SEQ
    assert dec_req.rid == rid_dec and dec_req.bucket == 1
    # per-request floors are priced at the request's bucket
    assert eng._req_floor_s(dec_req) < eng._req_floor_s(pre_req)
    # backlog sums per-bucket estimates (satellite: was per-tenant
    # default-graph makespans for every queued request)
    assert eng.backlog_s() == pytest.approx(
        eng._req_floor_s(pre_req) + eng._req_floor_s(dec_req))
    with pytest.raises(ValueError):
        eng.submit(0, seq_len=16)                   # vision has no spec
    eng.run()
    assert all(r.deadline_met is not False for r in eng.done.values())


def test_engine_decode_corounds_and_edf_under_mixed_buckets(lm_session):
    """Decode requests co-schedule with the vision tenant under the
    decode-bucket plan, and EDF winnability uses per-request bucket
    floors: a decode request with a deadline only it can win must
    dispatch before an earlier-queued prefill whose floor overshoots."""
    from repro.serve.admission import RoundComposer
    eng, compiler = _engine(lm_session, composer=RoundComposer())
    dec_floor = eng._floor_s(1, 1)
    pre_floor = eng._floor_s(1, MAX_SEQ)
    assert dec_floor < pre_floor
    # prefill first into the queue (no deadline), decode second with a
    # winnable deadline — EDF serves winnable deadlines before
    # deadline-less FIFO order, so the decode bypasses the prefill
    eng.submit(1, seq_len=MAX_SEQ)
    rid = eng.submit(1, seq_len=1,
                     deadline_s=2.0 * (dec_floor + pre_floor))
    eng.submit(0)
    compiler.run_pending()
    eng.step()
    assert rid in eng.done
    assert eng.done[rid].deadline_met is True
    eng.run()
    rep = eng.report()
    assert rep["starvation_events"] == 0
    assert rep["served"] == 3


def _fresh_session():
    from repro.core.deploy import CompileRequest, DeploymentSession
    from repro.models.lm_graphs import lm_tenant
    from repro.soc.testbed import dense_chain, two_acc_soc
    soc, pats = two_acc_soc(512, 8.0)
    lm_graph, lm_spec = lm_tenant("rwkv6", max_seq=MAX_SEQ, d=64, ffn=128)
    session = DeploymentSession(CompileRequest(
        graphs=[dense_chain("vision", [64, 64, 64]), lm_graph],
        soc=soc, patterns=pats, requested_tiles=4, time_budget_s=0.5,
        joint_time_budget_s=1.0, lazy_joint_time_budget_s=0.5,
        incremental_time_budget_s=0.5, shape_buckets={1: lm_spec}))
    session.compile()
    return session


def test_engine_prefetch_covers_decode_transition():
    """The prefill->decode bucket transition lands on a warm plan when
    the prefetcher runs between arrival and dispatch; without it the
    same trace pays floor rounds.  Each arm gets a FRESH session — a
    shared store would leak the warm arm's compiled lattice points into
    the cold arm."""
    def trace(prefetch):
        eng, compiler = _engine(_fresh_session(), prefetch=prefetch)
        for step in range(5):
            eng.submit(1, seq_len=MAX_SEQ if step == 0 else 1)
            eng.submit(0)
            compiler.run_pending()
            eng.step()
        eng.run()
        return eng.report()

    warm = trace(prefetch=True)
    cold = trace(prefetch=False)
    assert warm["served"] == cold["served"] == 10
    assert warm["floor_rounds"] == 0
    assert cold["floor_rounds"] >= 1
    assert warm["async_compiler"]["prefetch_compiled"] >= 1
    assert warm["starvation_events"] == cold["starvation_events"] == 0


def test_compiler_walks_the_bucket_ladder(lm_session):
    """Observing a dispatched lattice point enqueues its one-rung bucket
    neighbors (decode-ward rung weighted double) alongside the occupancy
    joins/leaves."""
    from repro.serve.compiler_thread import BackgroundCompiler
    compiler = BackgroundCompiler(lm_session, start=False, prefetch=True)
    key = lm_session.plan_key([0, 1], {1: 4})
    compiler.observe(key)
    compiler.run_pending()
    hinted = lm_session.store.keys()
    # both ladder rungs of t1@4 at this occupancy were compiled
    assert make_plan_key([0, 1], {1: 2}) in hinted
    assert make_plan_key([0, 1], {1: 8}) in hinted
