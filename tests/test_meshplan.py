"""Mesh partitioner: CP strategy selection + spec validity for every arch.

Runs on the single real CPU device by constructing *abstract* meshes from
jax.sharding.Mesh over a reshaped device array is impossible with 1 device,
so these tests call the strategy CP directly (`_choose`) and validate rule
synthesis paths with a 1x1 mesh."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import registry
from repro.core import meshplan


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_strategy_cp_runs_and_is_feasible(arch):
    cfg = registry.get_config(arch)
    chosen, lanes, notes = meshplan._choose(16, cfg, 4096 * 256, 16)
    assert set(chosen) == {"attention", "ffn", "vocab"}
    assert all(v >= 0 for v in lanes.values())


def test_moe_ep_divisibility_drives_strategy():
    """olmoe has 64 experts (divisible by 16 -> EP allowed); granite has 40
    (not divisible -> EP infeasible, CP must pick another strategy)."""
    olmoe = registry.get_config("olmoe-1b-7b")
    granite = registry.get_config("granite-moe-3b-a800m")
    ch_o, _, _ = meshplan._choose(16, olmoe, 4096 * 256, 16)
    ch_g, _, notes_g = meshplan._choose(16, granite, 4096 * 256, 16)
    assert ch_o["ffn"] in ("expert_parallel", "expert_ffn_tp")
    assert ch_g["ffn"] != "expert_parallel"
    assert any("infeasible" in n for n in notes_g)


def test_vocab_tp_requires_divisibility():
    """granite vocab 49155 is not divisible by 16: vocab_tp infeasible."""
    granite = registry.get_config("granite-moe-3b-a800m")
    ch, _, _ = meshplan._choose(16, granite, 4096 * 256, 16)
    assert ch["vocab"] == "dp_replicated"


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_rules_cover_every_param(arch):
    cfg = registry.get_smoke_config(arch)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    plan = meshplan.plan_model(cfg, mesh, "train", 8, 64)
    params = registry.param_specs(cfg)
    sh = meshplan.tree_shardings(plan, mesh, params)
    # every leaf got a NamedSharding whose spec rank <= leaf rank
    for (path, leaf), s in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))):
        assert hasattr(s, "spec")
        assert len(s.spec) <= len(leaf.shape), (path, s.spec, leaf.shape)


def test_plan_notes_record_infeasibilities():
    granite = registry.get_config("granite-moe-3b-a800m")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    plan = meshplan.plan_model(granite, mesh, "train", 8, 64)
    assert isinstance(plan.notes, list)
