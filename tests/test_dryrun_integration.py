"""Integration: the multi-pod dry-run entrypoint lowers + compiles a cell
end-to-end in a fresh subprocess (it needs 512 virtual devices, which must
not leak into this test process)."""

import os
import subprocess
import sys

import pytest

# excluded from the fast CI lane (-m "not slow")
pytestmark = pytest.mark.slow

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.parametrize("arch,shape", [
    ("internlm2-1.8b", "decode_32k"),
    ("rwkv6-3b", "long_500k"),
])
def test_dryrun_cell_subprocess(arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--mesh", "single",
         "--out", os.path.join(ROOT, "artifacts", "dryrun_test")],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "0 FAIL" in out.stdout


def test_dryrun_skip_rules_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "hubert-xlarge", "--shape", "decode_32k",
         "--mesh", "single",
         "--out", os.path.join(ROOT, "artifacts", "dryrun_test")],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0
    assert "SKIP" in out.stdout
