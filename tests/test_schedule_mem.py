"""Stage-2 scheduler + memory plan: constraint validation on real models."""

import pytest

from repro.core.api import compile_model
from repro.core.memplan import validate_plan
from repro.core.schedule import validate_schedule
from repro.models import edge
from repro.soc.carfield import carfield_patterns, carfield_soc

# excluded from the fast CI lane (-m "not slow")
pytestmark = pytest.mark.slow

SOC = carfield_soc()
PATS = carfield_patterns()


@pytest.mark.parametrize("model", ["autoencoder", "ds_cnn", "resnet",
                                   "resnext50_block"])
@pytest.mark.parametrize("mode", ["match", "matcha"])
def test_schedule_constraints(model, mode):
    cm = compile_model(edge.ALL_MODELS[model](), SOC, PATS, mode=mode,
                       time_budget_s=2.0)
    errs = validate_schedule(cm.plan)
    assert errs == [], errs


@pytest.mark.parametrize("model", ["autoencoder", "resnet", "mobilenet"])
def test_memory_plan_valid(model):
    cm = compile_model(edge.ALL_MODELS[model](), SOC, PATS, mode="matcha",
                       time_budget_s=2.0)
    errs = validate_plan(cm.plan.memory)
    assert errs == [], errs
    assert cm.plan.memory.peak <= SOC.l2.size


def test_sequential_modes_never_overlap_compute():
    cm = compile_model(edge.resnet(), SOC, PATS, mode="match",
                       time_budget_s=2.0)
    comp = sorted((n for n in cm.plan.nodes.values()
                   if n.resource != "dma"), key=lambda n: n.start)
    for a, b in zip(comp, comp[1:]):
        assert a.end <= b.start + 1e-6


def test_utilization_sums_sane():
    cm = compile_model(edge.resnet50_block(), SOC, PATS, mode="matcha",
                       time_budget_s=2.0)
    util = cm.plan.utilization()
    assert all(0.0 <= u <= 1.0 + 1e-9 for u in util.values())
    # the paper's whole point: both accelerators busy
    assert util.get("spatz", 0) > 0.3
    assert util.get("pulp", 0) > 0.3
