"""Static plan analyzer (PR 7): clean plans analyze clean at strict,
every rule's seeded mutation is caught (the analyzer has teeth), the
legacy validators are analyzer shims, the deployment session enforces
the strict/warn knob, and the concurrency lint holds on the serving
layer."""

import itertools
import pathlib

import pytest

from repro.analysis import Severity, analyze, analyze_errors
from repro.analysis.lockcheck import check_paths, check_source
from repro.analysis.mutate import MUTATORS, check_rules, clone_plan, mutate
from repro.analysis.scan_mixes import mixes_from_baseline, plans_for_mix
from repro.core.api import compile_multi
from repro.core.deploy import CompileRequest, DeploymentSession
from repro.core.memplan import validate_plan
from repro.core.schedule import validate_multi_schedule, validate_schedule
from repro.soc.testbed import dense_chain, two_acc_soc

REPO = pathlib.Path(__file__).resolve().parent.parent
REQUESTED_TILES = 4
TIME_BUDGET_S = 0.5


@pytest.fixture(scope="module")
def mc():
    """Cheap three-tenant testbed compile: full-house co-schedule plus
    lazily compiled occupancy subsets — the mutation substrate."""
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain("a", [64, 64, 64]),
              dense_chain("b", [48, 48, 48]),
              dense_chain("c", [32, 32, 32])]
    return compile_multi(graphs, soc, pats,
                         requested_tiles=REQUESTED_TILES,
                         time_budget_s=TIME_BUDGET_S)


# ---------------------------------------------------------------------------
# Clean plans analyze clean
# ---------------------------------------------------------------------------


def test_testbed_plans_have_no_error_diagnostics(mc):
    """Full house, every occupancy subset, and every compile-alone plan
    carry zero ERROR-severity diagnostics."""
    plans = {"full": mc.plan}
    for r in (1, 2):
        for ids in itertools.combinations(range(3), r):
            plans[str(ids)] = mc.plan_for(list(ids))
    for i, cm in enumerate(mc.singles):
        plans[f"single{i}"] = cm.plan
    for label, plan in plans.items():
        assert analyze_errors(plan) == [], label


def test_session_strict_analysis_counts(mc):
    """The session analyzed every plan it stored (strict is the default)
    and found no errors."""
    mc.plan_for([0, 1])               # force at least one subset compile
    stats = mc.session.analysis_stats()
    assert stats["mode"] == "strict"
    assert stats["plans_analyzed"] >= 2   # full house + the subset
    assert stats["errors"] == 0


BASELINE = REPO / "benchmarks" / "baseline.json"


@pytest.mark.parametrize(
    "mix", [pytest.param(m, id="+".join(m))
            for m in mixes_from_baseline(str(BASELINE))])
def test_benchmark_mix_plans_analyze_clean(mix):
    """Every schedule the session emits for the benchmark mixes — full
    house, all PlanStore occupancies, compile-alone plans — analyzes
    with zero ERROR diagnostics (the same sweep the CI ``scan_mixes``
    lane runs)."""
    for label, plan in plans_for_mix(mix, TIME_BUDGET_S):
        assert analyze_errors(plan) == [], (mix, label)


# ---------------------------------------------------------------------------
# Mutation harness: every rule has teeth
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", sorted(MUTATORS))
def test_rule_catches_its_mutation(mc, rule):
    """Each analyzer rule must flag the hazard its mutator injects into
    an otherwise-clean co-schedule (ERROR severity, correct rule id)."""
    mutated = mutate(mc.plan, rule)
    diags = analyze(mutated)
    assert any(d.rule == rule and d.severity >= Severity.ERROR
               for d in diags), (rule, [str(d) for d in diags])
    # and the mutation did not leak into the shared fixture plan
    assert analyze_errors(mc.plan) == []


def test_check_rules_all_fire_on_multi(mc):
    fired = check_rules(mc.plan)
    assert set(fired) == set(MUTATORS)
    assert all(fired.values()), fired


def test_check_rules_all_fire_on_single(mc):
    """Single-model plans exercise every rule except tenant isolation
    (PA006 needs budgets, which only multi plans carry)."""
    fired = check_rules(mc.singles[0].plan)
    assert set(fired) == set(MUTATORS) - {"PA006"}
    assert all(fired.values()), fired


def test_clone_plan_is_deep_enough(mc):
    """Mutating a clone must never write through to the original."""
    clone = clone_plan(mc.plan)
    first = mc.plan.order[0]
    clone.nodes[first].start += 1.0
    clone.memory.allocations[0].addr += 64
    assert mc.plan.nodes[first].start != clone.nodes[first].start
    assert mc.plan.memory.allocations[0].addr != \
        clone.memory.allocations[0].addr


# ---------------------------------------------------------------------------
# Legacy validators are analyzer shims
# ---------------------------------------------------------------------------


def test_validators_flag_mutations_with_rule_ids(mc):
    assert validate_multi_schedule(mc.plan) == []
    errs = validate_multi_schedule(mutate(mc.plan, "PA001"))
    assert errs and any("PA001" in e for e in errs)
    single = mc.singles[0].plan
    assert validate_schedule(single) == []
    errs = validate_schedule(mutate(single, "PA002"))
    assert errs and any("PA002" in e for e in errs)


def test_multi_validator_now_checks_l2_aliasing(mc):
    """PR-7 coverage gain: ``validate_multi_schedule`` flags L2 address
    aliasing between concurrently-live allocations (it only checked
    precedence/overlap/residency before)."""
    errs = validate_multi_schedule(mutate(mc.plan, "PA005"))
    assert errs and any("PA005" in e for e in errs)


def test_memplan_validator_shares_analyzer_epsilon(mc):
    mem = mc.plan.memory
    assert validate_plan(mem) == []
    errs = validate_plan(mutate(mc.plan, "PA005").memory)
    assert errs and any("PA005" in e for e in errs)


# ---------------------------------------------------------------------------
# Session wiring: the strict/warn knob
# ---------------------------------------------------------------------------


def _bare_session(analysis):
    soc, pats = two_acc_soc(64, 8.0)
    req = CompileRequest(graphs=[dense_chain("a", [32, 32])], soc=soc,
                         patterns=pats, time_budget_s=TIME_BUDGET_S,
                         analysis=analysis)
    return DeploymentSession(req)


def test_strict_mode_raises_on_error_diagnostics(mc):
    session = _bare_session("strict")
    with pytest.raises(RuntimeError, match="PA001"):
        session._analyze(mutate(mc.plan, "PA001"), "infeasible co-schedule")
    assert session.analysis_stats()["errors"] >= 1


def test_warn_mode_records_instead_of_raising(mc):
    session = _bare_session("warn")
    bad = mutate(mc.plan, "PA003")
    assert session._analyze(bad, "ctx") is bad      # plan still ships
    stats = session.analysis_stats()
    assert stats["mode"] == "warn"
    assert stats["errors"] >= 1
    assert stats["by_rule"].get("PA003", 0) >= 1
    assert any("PA003" in f for f in stats["findings"])


def test_off_mode_skips_the_analyzer(mc):
    session = _bare_session("off")
    assert session._analyze(mutate(mc.plan, "PA001"), "ctx") is not None
    assert session.analysis_stats()["plans_analyzed"] == 0


def test_invalid_analysis_mode_rejected():
    soc, pats = two_acc_soc(64, 8.0)
    with pytest.raises(ValueError, match="analysis"):
        CompileRequest(graphs=[dense_chain("a", [32, 32])], soc=soc,
                       patterns=pats, analysis="lenient")


# ---------------------------------------------------------------------------
# Concurrency lint
# ---------------------------------------------------------------------------


def test_lockcheck_clean_on_serving_layer():
    assert check_paths([str(REPO / "src" / "repro" / "serve")]) == []


def test_lockcheck_flags_unlocked_write():
    src = (
        "import threading\n"
        "class Store:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "        self.items = {}\n"
        "    def put(self, k, v):\n"
        "        with self._lock:\n"
        "            self.items[k] = v\n"
        "    def drop(self, k):\n"
        "        del self.items[k]\n"
    )
    vs = check_source(src, "snippet.py")
    assert any(v.method == "drop" and v.field == "items" for v in vs)


def test_lockcheck_honors_caller_holds_the_lock_marker():
    src = (
        "import threading\n"
        "class Store:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "        self.items = {}\n"
        "    def put(self, k, v):\n"
        "        with self._lock:\n"
        "            self._put(k, v)\n"
        "    def _put(self, k, v):\n"
        "        \"\"\"Caller holds the lock.\"\"\"\n"
        "        self.items[k] = v\n"
    )
    assert check_source(src, "snippet.py") == []


def test_lockcheck_enforces_docstring_declared_guards():
    """A field the class docstring declares lock-guarded is enforced
    even when no locked write is ever seen (the inference blind spot the
    worker-pool state exposed)."""
    src = (
        "import threading\n"
        "class Pool:\n"
        "    \"\"\"Worker pool.\n"
        "\n"
        "    Lock-guarded: _recent, _hints\n"
        "    \"\"\"\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "        self._recent = {}\n"
        "        self._hints = {}\n"
        "    def peek(self):\n"
        "        return len(self._recent)\n"
        "    def ok(self):\n"
        "        with self._lock:\n"
        "            return len(self._hints)\n"
    )
    vs = check_source(src, "snippet.py")
    assert [(v.method, v.field, v.access) for v in vs] == \
        [("peek", "_recent", "read")]
    # without the declaration the same read is invisible to inference
    undeclared = src.replace("    Lock-guarded: _recent, _hints\n", "")
    assert check_source(undeclared, "snippet.py") == []


def test_lockcheck_declared_guards_on_background_compiler():
    """The real ``BackgroundCompiler`` declares its pool + prefetcher
    state; corrupting one of its lock blocks must trip the lint."""
    path = REPO / "src" / "repro" / "serve" / "compiler_thread.py"
    src = path.read_text()
    assert "Lock-guarded: _queued" in src
    assert check_source(src, str(path)) == []
    broken = src.replace("        with self._lock:\n"
                         "            self._recent.pop(key, None)",
                         "        if True:\n"
                         "            self._recent.pop(key, None)")
    assert broken != src
    vs = check_source(broken, str(path))
    assert any(v.field == "_recent" and v.access == "write" for v in vs)
