"""Contention-aware re-tiling (PR 2): makespan dominance chain on random
mixes, a forced-contention case where shrunk-budget re-tiling reduces
``SharedL2Allocator`` evictions, and bitwise numerics of re-tiled
co-schedules."""

import numpy as np
import pytest

from _hypo import given, settings, st

from repro.core.api import compile_multi
from repro.core.runtime import (execute_multi_plan, execute_plan,
                                init_inputs, init_params,
                                multi_plan_matches_oracle)
from repro.core.schedule import (_search_coschedule, contention_hints,
                                 default_budgets, validate_multi_schedule)
from repro.core.tiling import Contention
from repro.soc.testbed import dense_chain, forced_contention_setup, \
    two_acc_soc


@pytest.fixture(scope="module")
def forced_contention_mc():
    """Deep dense chains whose weights cycle through a shared L2 that holds
    only ~3 of them: the compile-alone tilings split every layer across
    both accelerators, stretching each weight's residency across the
    co-tenant's interleaved kernels — contention evictions."""
    soc, pats, graphs = forced_contention_setup()
    mc = compile_multi(graphs, soc, pats, requested_tiles=8,
                       time_budget_s=0.5)
    return mc, soc


def test_forced_contention_retiling_reduces_evictions(forced_contention_mc):
    """The co-schedule of sole-occupancy tilings over-subscribes the shared
    L2; re-tiling under the shrunk, contention-adjusted budgets must win
    the makespan without paying more SharedL2Allocator evictions.  (The
    eviction comparison is <=, not <: since the schedulers pin in-flight
    accesses against eviction — a swap-out may no longer race a running
    kernel's reads — both sides' eviction counts reflect the honest,
    hazard-free residency windows, under which the two plans can tie.)"""
    mc, soc = forced_contention_mc
    forced, err = _search_coschedule([cm.tiled for cm in mc.singles], soc,
                                     default_budgets(soc, 2), 3, 0)
    assert forced is not None, err
    assert mc.retiled
    assert mc.plan.mode != "sequential"
    assert mc.plan.makespan < forced.makespan
    assert mc.plan.memory.evictions <= forced.memory.evictions
    assert mc.plan.memory.evictions > 0      # still genuinely contended
    # and the full dominance chain holds
    assert mc.plan.makespan <= mc.baseline_makespan_cycles + 1e-6
    assert mc.baseline_makespan_cycles <= \
        mc.sequential_makespan_cycles + 1e-6


def test_forced_contention_plan_feasible(forced_contention_mc):
    mc, soc = forced_contention_mc
    assert validate_multi_schedule(mc.plan) == []
    assert mc.plan.memory.peak <= soc.l2.size


def test_retiled_numerics_match_oracle(forced_contention_mc):
    """Re-tiled co-scheduled execution == per-model whole-graph oracle."""
    mc, _ = forced_contention_mc
    assert mc.retiled
    assert multi_plan_matches_oracle(mc.plan)


def test_retiled_numerics_bitmatch_tenant_plan(forced_contention_mc):
    """Interleaving re-tiled tenants must not perturb numerics at all:
    each tenant's outputs are bit-identical to executing a single-model
    plan over the SAME re-tiled graph alone."""
    mc, _ = forced_contention_mc
    params = [init_params(g, 2 * i) for i, g in enumerate(mc.graphs)]
    inputs = [init_inputs(g, 2 * i + 1) for i, g in enumerate(mc.graphs)]
    multi_out = execute_multi_plan(mc.plan, inputs, params)
    for i, g in enumerate(mc.graphs):
        single_out = execute_plan(mc.tenant_plan(i), inputs[i], params[i])
        for t in g.outputs:
            assert np.array_equal(np.asarray(single_out[t]),
                                  np.asarray(multi_out[i][t])), (g.name, t)


def test_contention_hints_shape(forced_contention_mc):
    """Hints summarize co-residency: each tenant sees its budget, its
    co-residents' (not its own) device load, and a DMA factor >= 1."""
    mc, soc = forced_contention_mc
    hints = contention_hints(mc.baseline_plan, soc)
    assert len(hints) == 2
    for h in hints:
        assert isinstance(h, Contention)
        assert h.l2_budget == mc.baseline_plan.budgets[0]
        assert h.dma_scale >= 1.0
        assert all(v >= 0.0 for v in h.device_load.values())


def test_retile_disabled_reproduces_baseline():
    """``retile_for_contention=False`` must reproduce the PR-1 behaviour
    exactly (same winning makespan as the baseline plan)."""
    soc, pats = two_acc_soc(56, 12.0)
    graphs = [dense_chain("a", [96] * 6), dense_chain("b", [96] * 6)]
    mc = compile_multi(graphs, soc, pats, requested_tiles=4,
                       time_budget_s=0.5, retile_for_contention=False)
    assert not mc.retiled
    assert mc.plan.makespan == mc.baseline_makespan_cycles


WIDTHS = [16, 32, 48, 64]


@settings(max_examples=4, deadline=None)
@given(st.data())
def test_retile_makespan_dominance_chain(data):
    """Property: on random mixes, re-tiled co-scheduled makespan <= PR-1
    co-scheduled makespan <= sequential concatenation."""
    n_layers = data.draw(st.integers(2, 3))
    l2_kib = data.draw(st.sampled_from([48, 64, 96]))
    soc, pats = two_acc_soc(l2_kib, 8.0)
    n_tenants = data.draw(st.integers(2, 3))
    graphs = []
    for i in range(n_tenants):
        widths = [data.draw(st.sampled_from(WIDTHS))
                  for _ in range(n_layers + 1)]
        graphs.append(dense_chain(f"m{i}", widths))
    mc = compile_multi(graphs, soc, pats, requested_tiles=4,
                       time_budget_s=0.5)
    assert mc.plan.makespan <= mc.baseline_makespan_cycles + 1e-6
    assert mc.baseline_makespan_cycles <= \
        mc.sequential_makespan_cycles + 1e-6
    assert validate_multi_schedule(mc.plan) == []
