"""CP solver: branch & bound vs exhaustive search (property-based)."""

import pytest
from _hypo import given, settings, st

from repro.core import cpsolver


def _random_model(draw):
    n = draw(st.integers(2, 4))
    m = cpsolver.CpModel()
    for i in range(n):
        m.new_int(0, draw(st.integers(1, 5)))
    # a couple of linear constraints
    for _ in range(draw(st.integers(1, 3))):
        coeffs = {i: draw(st.integers(-3, 3)) for i in range(n)}
        const = -draw(st.integers(0, 12))
        m.add_le({i: float(c) for i, c in coeffs.items()}, float(const))
    # one equality: sum of a subset equals a reachable value
    idx = list(range(n))[: draw(st.integers(1, n))]
    target = draw(st.integers(0, sum(m._hi[i] for i in idx)))
    m.add_eq({i: 1.0 for i in idx}, -float(target))
    # two makespan loads
    for _ in range(2):
        m.add_load({i: float(draw(st.integers(0, 4))) for i in range(n)},
                   float(draw(st.integers(0, 3))))
    return m


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_bnb_matches_bruteforce(data):
    m = _random_model(data.draw)
    try:
        ref = cpsolver.brute_force(m)
    except cpsolver.Infeasible:
        with pytest.raises(cpsolver.Infeasible):
            m.solve(time_budget_s=5.0)
        return
    sol = m.solve(time_budget_s=5.0)
    assert sol.optimal
    assert abs(sol.objective - ref.objective) < 1e-6
    assert m._feasible(sol.values)


def test_hint_feasible_is_used_as_incumbent():
    m = cpsolver.CpModel()
    a = m.new_int(0, 10)
    b = m.new_int(0, 10)
    m.add_eq({a: 1.0, b: 1.0}, -10.0)
    m.add_load({a: 2.0})
    m.add_load({b: 3.0})
    sol = m.solve(hint=[6, 4], time_budget_s=5.0)
    assert sol.objective == 12.0      # optimal: a=6,b=4 -> max(12, 12)
    assert m._feasible(sol.values)


def test_infeasible_raises():
    m = cpsolver.CpModel()
    a = m.new_int(0, 3)
    m.add_ge({a: 1.0}, -5.0)          # a + (-5) >= 0, i.e. a >= 5
    with pytest.raises(cpsolver.Infeasible):
        m.solve(time_budget_s=2.0)
