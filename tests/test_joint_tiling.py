"""Joint cross-tenant tiling CP (the PR-4 tentpole): one constraint
program over all tenants' tile variables — coordinated solutions, the
``joint <= best-response <= sequential`` property, per-occupancy re-tiling
with bitwise numerics against per-tiling reference schedules, the solver
time budget + best-response fallback, the ``PlanStore`` LRU bound, and the
configurable ``Objective`` tie-break chains."""

import dataclasses

import numpy as np
import pytest

from _hypo import given, settings, st

from repro.core import cpsolver
from repro.core.api import compile_multi
from repro.core.deploy import (CompileRequest, DeploymentSession, Objective,
                               PlanStore, default_strategy_names,
                               get_strategy)
from repro.core.rewrite import rewrite
from repro.core.runtime import (execute_multi_plan, execute_plan,
                                init_inputs, init_params)
from repro.core.schedule import validate_multi_schedule
from repro.core.tiling import (JointTilingProblem, conservation_ok,
                               optimize_tiling)
from repro.soc.testbed import dense_chain, two_acc_soc

REQUESTED_TILES = 4
TIME_BUDGET_S = 0.5
JOINT_BUDGET_S = 2.0


def make_session(graphs, soc, pats, **kw) -> DeploymentSession:
    kw.setdefault("requested_tiles", REQUESTED_TILES)
    kw.setdefault("time_budget_s", TIME_BUDGET_S)
    kw.setdefault("joint_time_budget_s", JOINT_BUDGET_S)
    return DeploymentSession(CompileRequest(
        graphs=graphs, soc=soc, patterns=pats, **kw))


def three_tenant_session(**kw) -> DeploymentSession:
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain("a", [64, 64, 64]),
              dense_chain("b", [48, 48, 48]),
              dense_chain("c", [32, 32, 32])]
    return make_session(graphs, soc, pats, **kw)


@pytest.fixture(scope="module")
def session():
    return three_tenant_session()


@pytest.fixture(scope="module")
def mc(session):
    return session.compile()


# ---------------------------------------------------------------------------
# JointCpModel: the multi-tenant composition layer
# ---------------------------------------------------------------------------


def test_joint_cp_model_merges_keyed_loads():
    """Loads with the same key accumulate across tenants (the shared-device
    coupling); the objective is the max over merged keys."""
    jm = cpsolver.JointCpModel()
    x0 = jm.new_int(0, 0, 4, "x0")
    x1 = jm.new_int(1, 0, 4, "x1")
    jm.add_eq({x0: 1.0}, -2.0)           # x0 == 2
    jm.add_eq({x1: 1.0}, -3.0)           # x1 == 3
    jm.add_load("dev", {x0: 1.0})
    jm.add_load("dev", {x1: 1.0})        # same key: summed
    jm.add_load("other", {x0: 1.0})
    sol = jm.solve(time_budget_s=1.0)
    assert sol.objective == pytest.approx(5.0)   # 2 + 3 on "dev"
    assert jm.tenant_values(sol.values, 0) == {x0: 2}
    assert jm.tenant_values(sol.values, 1) == {x1: 3}


def test_joint_cp_model_shared_capacity():
    """One capacity constraint spanning both tenants' variables forces the
    joint optimum to trade them off (neither tenant can max out alone)."""
    jm = cpsolver.JointCpModel()
    x0 = jm.new_int(0, 0, 10, "x0")
    x1 = jm.new_int(1, 0, 10, "x1")
    # maximize-ish: makespan term rewards balance; capacity caps the sum
    jm.add_capacity({x0: 1.0, x1: 1.0}, 10.0)
    jm.add_load("d0", {x0: -1.0}, const=10.0)    # 10 - x0
    jm.add_load("d1", {x1: -1.0}, const=10.0)    # 10 - x1
    sol = jm.solve(time_budget_s=1.0)
    assert sol.values[x0] + sol.values[x1] <= 10
    assert sol.objective == pytest.approx(5.0)   # balanced split 5/5


def test_joint_cp_model_zero_budget_raises():
    jm = cpsolver.JointCpModel()
    jm.new_int(0, 0, 1, "x")
    with pytest.raises(cpsolver.Infeasible):
        jm.solve(time_budget_s=0.0)


# ---------------------------------------------------------------------------
# JointTilingProblem: coordinated per-tenant solutions from one solve
# ---------------------------------------------------------------------------


def joint_setup():
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain("a", [64, 64, 64]),
              dense_chain("b", [48, 48, 48])]
    return soc, pats, graphs


def test_joint_problem_solutions_conserve_tiles():
    soc, pats, graphs = joint_setup()
    prob = JointTilingProblem(graphs, soc, pats,
                              requested_tiles=REQUESTED_TILES)
    sols = prob.solve(time_budget_s=JOINT_BUDGET_S)
    assert len(sols) == len(graphs)
    for g, s in zip(graphs, sols):
        assert conservation_ok(g, s)
        assert rewrite(g, soc, s).repairs == 0


def test_joint_warm_start_is_feasible():
    """Per-tenant compile-alone solutions always map to a feasible joint
    start (the overflow variable absorbs their combined footprint)."""
    soc, pats, graphs = joint_setup()
    alone = [optimize_tiling(g, soc, pats,
                             requested_tiles=REQUESTED_TILES,
                             time_budget_s=TIME_BUDGET_S) for g in graphs]
    prob = JointTilingProblem(graphs, soc, pats,
                              requested_tiles=REQUESTED_TILES)
    hint = prob.warm_start(alone)
    assert hint is not None
    prob.joint._finalize()
    assert prob.joint.model._feasible(hint)


def test_joint_objective_not_worse_than_warm_start():
    """The joint solve only moves away from the warm start when the joint
    (shared-resource) objective improves."""
    soc, pats, graphs = joint_setup()
    alone = [optimize_tiling(g, soc, pats,
                             requested_tiles=REQUESTED_TILES,
                             time_budget_s=TIME_BUDGET_S) for g in graphs]
    prob = JointTilingProblem(graphs, soc, pats,
                              requested_tiles=REQUESTED_TILES)
    hint = prob.warm_start(alone)
    prob.joint._finalize()             # loads merge at solve time
    warm_obj = prob.joint.model._obj_value(hint)
    sols = prob.solve(warm=alone, time_budget_s=JOINT_BUDGET_S)
    assert sols[0].objective <= warm_obj + 1e-6


# ---------------------------------------------------------------------------
# The acceptance property: joint <= best-response <= PR-1 <= sequential
# ---------------------------------------------------------------------------


def assert_ordering(mc):
    joint = mc.plan.makespan
    br = mc.best_response_makespan_cycles
    pr1 = mc.baseline_makespan_cycles
    seq = mc.sequential_makespan_cycles
    assert joint <= br + 1e-6, (joint, br)
    assert br <= pr1 + 1e-6, (br, pr1)
    assert pr1 <= seq + 1e-6, (pr1, seq)


def test_joint_le_best_response_le_sequential(mc):
    assert_ordering(mc)


def test_best_response_plan_matches_joint_free_session():
    """Phase A of the joint session's fixpoint IS the best-response
    session: a session compiled without ``joint-cp`` lands on the same
    makespan, so 'joint <= best-response' compares against the real PR-2/3
    result, not a strawman."""
    joint_s = three_tenant_session()
    joint_mc = joint_s.compile()
    br_names = [n for n in default_strategy_names("matcha")
                if n != "joint-cp"]
    br_s = three_tenant_session(strategies=br_names)
    br_mc = br_s.compile()
    assert joint_s.best_response_plan is not None
    assert joint_s.best_response_plan.makespan == \
        pytest.approx(br_mc.plan.makespan)
    assert joint_mc.plan.makespan <= br_mc.plan.makespan + 1e-6


WIDTHS = [16, 32, 48, 64, 96]


@settings(max_examples=3, deadline=None)
@given(st.data())
def test_joint_property_random_mixes(data):
    """joint <= best-response <= PR-1 <= sequential on random 2-3 tenant
    mixes, and every stored occupancy beats its compile-alone concat."""
    l2_kib = data.draw(st.sampled_from([48, 64, 96]))
    soc, pats = two_acc_soc(l2_kib, 8.0)
    n = data.draw(st.integers(2, 3))
    graphs = [dense_chain(f"m{i}",
                          [data.draw(st.sampled_from(WIDTHS))
                           for _ in range(3)])
              for i in range(n)]
    mc = compile_multi(graphs, soc, pats, requested_tiles=REQUESTED_TILES,
                       time_budget_s=TIME_BUDGET_S,
                       joint_time_budget_s=JOINT_BUDGET_S)
    assert_ordering(mc)
    for ids in ([i] for i in range(n)):
        plan = mc.plan_for(ids)
        assert validate_multi_schedule(plan) == []
        alone = sum(mc.singles[i].plan.makespan for i in ids)
        assert plan.makespan <= alone + 1e-6


# ---------------------------------------------------------------------------
# Per-occupancy re-tiling: numerics + the no-negative-gain floor
# ---------------------------------------------------------------------------


def all_subsets(n):
    out = []
    for mask in range(1, 2 ** n):
        out.append([i for i in range(n) if mask >> i & 1])
    return out


def test_every_occupancy_beats_compile_alone_concat(mc):
    """The acceptance criterion behind the benchmark's negative-gain fix:
    every occupancy's co-schedule beats (or ties) running its members'
    compile-alone schedules back-to-back."""
    for ids in all_subsets(len(mc.graphs)):
        plan = mc.plan_for(ids)
        assert validate_multi_schedule(plan) == []
        alone = sum(mc.singles[i].plan.makespan for i in ids)
        assert plan.makespan <= alone + 1e-6, (ids, plan.makespan, alone)


def test_bitwise_numerics_every_served_occupancy(session, mc):
    """For every occupancy the store serves, the co-scheduled execution is
    bitwise the per-tenant reference execution *of the tiling that
    occupancy actually uses* (per-occupancy re-tiling must not perturb
    numerics)."""
    for ids in all_subsets(len(mc.graphs)):
        plan = mc.plan_for(ids)
        params = [init_params(mc.graphs[i], 2 * i) for i in ids]
        inputs = [init_inputs(mc.graphs[i], 2 * i + 1) for i in ids]
        outs = execute_multi_plan(plan, inputs, params)
        for pos, i in enumerate(ids):
            ref = session.reference_plan(i, plan.tenants[pos])
            want = execute_plan(ref, inputs[pos], params[pos])
            for t in mc.graphs[i].outputs:
                assert np.array_equal(np.asarray(want[t]),
                                      np.asarray(outs[pos][t])), (ids, i, t)


def test_singleton_occupancy_prefers_alone_tiling(session, mc):
    """A lone tenant's occupancy plan is never worse than its compile-alone
    schedule (the full-house re-tiling no longer taxes low occupancy)."""
    for i in range(len(mc.graphs)):
        plan = mc.plan_for([i])
        assert plan.makespan <= mc.singles[i].plan.makespan + 1e-6


# ---------------------------------------------------------------------------
# Solver time budget -> best-response fallback
# ---------------------------------------------------------------------------


def test_joint_timeout_engages_best_response_fallback():
    """With a zero joint budget every joint solve fails; the session falls
    back to best-response re-tiling and still produces a valid plan whose
    makespan keeps the ordering guarantees."""
    s = three_tenant_session(joint_time_budget_s=0.0)
    mc = s.compile()
    assert s.joint_fallbacks >= 1
    assert s.joint_solves == 0
    assert validate_multi_schedule(mc.plan) == []
    assert_ordering(mc)
    assert mc.joint_stats()["fallbacks"] == s.joint_fallbacks


def test_joint_disabled_contributes_nothing():
    s = three_tenant_session(joint_tiling=False)
    mc = s.compile()
    assert s.joint_solves == 0 and s.joint_fallbacks == 0
    assert validate_multi_schedule(mc.plan) == []


def test_joint_fallback_delegates_when_sole_retiler():
    """joint-cp as the only re-tiling strategy + exhausted budget: the
    best-response fallback is delegated to contention-retile so the
    session still re-tiles."""
    s = three_tenant_session(
        strategies=["tile-centric", "all-or-nothing", "heft", "joint-cp"],
        joint_time_budget_s=0.0)
    mc = s.compile()
    assert s.joint_fallbacks >= 1
    assert validate_multi_schedule(mc.plan) == []


# ---------------------------------------------------------------------------
# PlanStore LRU bound
# ---------------------------------------------------------------------------


def _dummy_plan(tag: int):
    """Stand-in object; the store never introspects stored plans."""
    return ("plan", tag)


def test_plan_store_lru_evicts_least_recent():
    store = PlanStore(max_entries=2)
    store.co_plan([0], lambda: _dummy_plan(0))
    store.co_plan([1], lambda: _dummy_plan(1))
    store.co_plan([0], lambda: _dummy_plan(99))      # refresh [0]
    store.co_plan([2], lambda: _dummy_plan(2))       # evicts [1], not [0]
    assert store.lru_evictions == 1
    assert [0] in store and [2] in store
    assert [1] not in store
    # the evicted occupancy recompiles on its next miss
    before = store.compiles
    store.co_plan([1], lambda: _dummy_plan(1))
    assert store.compiles == before + 1
    assert store.stats()["evictions"] == 2          # [0] went this time


def test_plan_store_never_evicts_protected_full_house():
    store = PlanStore(max_entries=1)
    store.seed([0, 1, 2], _dummy_plan(7))
    store.protect([0, 1, 2])
    store.co_plan([0], lambda: _dummy_plan(0))
    store.co_plan([1], lambda: _dummy_plan(1))
    assert [0, 1, 2] in store                        # protected survives
    assert store.stats()["co_plans"] >= 1
    # tenant reference schedules are exempt from the co-plan bound
    store.seed_tenant((0, "sig"), _dummy_plan(5))
    store.co_plan([2], lambda: _dummy_plan(2))
    assert store.has_tenant((0, "sig"))


def test_plan_store_never_evicts_just_inserted_entry():
    """At max_entries=1 with a protected full house, a miss must not evict
    the plan it just compiled — the next lookup is a hit, not an endless
    recompile loop."""
    store = PlanStore(max_entries=1)
    store.seed([0, 1], _dummy_plan(9))
    store.protect([0, 1])
    store.co_plan([0], lambda: _dummy_plan(0))
    compiles = store.compiles
    store.co_plan([0], lambda: _dummy_plan(99))
    assert store.compiles == compiles            # hit, no recompile
    assert [0] in store and [0, 1] in store


def test_plan_store_max_entries_validation():
    with pytest.raises(ValueError):
        PlanStore(max_entries=0)
    with pytest.raises(ValueError):
        CompileRequest(graphs=[dense_chain("a", [16, 16])],
                       soc=two_acc_soc(64, 8.0)[0],
                       patterns=two_acc_soc(64, 8.0)[1],
                       store_max_entries=0)


def test_session_store_bound_respected():
    s = three_tenant_session(store_max_entries=2)
    mc = s.compile()                  # full house seeded + protected
    for ids in all_subsets(len(mc.graphs)):
        mc.plan_for(ids)
    stats = s.store.stats()
    assert stats["co_plans"] <= 2 + 1            # bound + protected full house
    assert stats["evictions"] > 0
    assert frozenset(range(len(mc.graphs))) in s.store.occupancies()


# ---------------------------------------------------------------------------
# Objective tie-break chains
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Mem:
    evictions: int


@dataclasses.dataclass
class _Dma:
    bytes: int


@dataclasses.dataclass
class _FakePlan:
    makespan: float
    memory: _Mem
    dmas: list
    retile_rounds: int = 0


def _plan(makespan, evictions=0, dma_bytes=0, retile_rounds=0):
    return _FakePlan(makespan, _Mem(evictions), [_Dma(dma_bytes)],
                     retile_rounds)


def test_objective_chain_order_matters():
    obj = Objective(tie_breaks=("dma_bytes", "evictions"))
    assert obj.chain == ("dma_bytes", "evictions")
    # dma_bytes decides first even though evictions disagree
    assert obj.better(_plan(10.0, evictions=9, dma_bytes=1),
                      _plan(10.0, evictions=0, dma_bytes=2))
    # dma_bytes tied -> evictions decide
    assert obj.better(_plan(10.0, evictions=0, dma_bytes=2),
                      _plan(10.0, evictions=9, dma_bytes=2))


def test_objective_retile_rounds_key():
    obj = Objective(tie_breaks=("retile_rounds",))
    assert obj.better(_plan(10.0, retile_rounds=0),
                      _plan(10.0, retile_rounds=2))
    # plans without the attribute score 0 (ExecutionPlan has no rounds)
    del_plan = _plan(10.0)
    assert obj.value(del_plan) == (10.0, 0.0)


def test_objective_chain_validation_and_legacy():
    with pytest.raises(ValueError):
        Objective(tie_breaks=("nope",))
    legacy = Objective(tie_break="evictions")
    assert legacy.chain == ("evictions",)
    assert Objective(tie_break=None).chain == ()
    # an explicit chain overrides the legacy single key
    both = Objective(tie_break="evictions", tie_breaks=("dma_bytes",))
    assert both.chain == ("dma_bytes",)


def test_objective_chain_threads_through_schedule_multi():
    """A chained objective drives the co-schedule search end to end (the
    duck-typed ``better`` is all schedule_multi needs — unchanged)."""
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain("a", [32, 32]), dense_chain("b", [32, 32])]
    s = make_session(graphs, soc, pats)
    s.objective = Objective(tie_breaks=("evictions", "dma_bytes",
                                        "retile_rounds"))
    mc = s.compile()
    assert validate_multi_schedule(mc.plan) == []
    assert_ordering(mc)


# ---------------------------------------------------------------------------
# Registry / defaults
# ---------------------------------------------------------------------------


def test_joint_strategy_registered_and_default():
    assert get_strategy("joint-cp").name == "joint-cp"
    assert get_strategy("joint-cp").joint
    assert get_strategy("decomposed-cp").name == "decomposed-cp"
    assert get_strategy("decomposed-cp").joint
    for mode in ("matcha", "matcha_nt"):
        names = default_strategy_names(mode)
        # the joint CPs run last, after the best-response strategies
        assert names[-2:] == ["joint-cp", "decomposed-cp"]
        off = default_strategy_names(mode, retile_for_contention=False)
        assert "joint-cp" not in off and "decomposed-cp" not in off


# ---------------------------------------------------------------------------
# Engine: singleton occupancy dispatch
# ---------------------------------------------------------------------------


def test_engine_singleton_uses_occupancy_plan(mc):
    from repro.serve.engine import MultiModelEngine
    eng = MultiModelEngine(mc)
    rid = eng.submit(1)
    done = eng.step()
    assert done == [rid]
    assert eng.co_rounds == 0
    assert eng.solo_dispatches == 1
    single = mc.plan_for([1])
    assert eng.done[rid].latency_ms == pytest.approx(
        mc.soc.cycles_to_ms(single.tenant_makespans[0]))
    rep = eng.report()
    assert rep["joint_cp"] == mc.joint_stats()
    assert "evictions" in rep["plan_store"]
