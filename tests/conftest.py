# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# the real single CPU device; only launch/dryrun.py forces 512 devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make the _hypo shim importable regardless of pytest's import mode
sys.path.insert(0, os.path.dirname(__file__))
