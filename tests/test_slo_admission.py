"""SLO-aware admission & round composition, property-tested under a
serving-trace harness.

The three core properties:

  (a) **No starvation** — on adversarial arrival traces every admitted
      request completes within a bounded number of serving rounds
      (``starvation_rounds * (depth_at_submit + 1)``: any queue head
      older than ``starvation_rounds`` head-tenure rounds is force-
      included in every candidate occupancy, so each request ahead pops
      within one tenure and then the request's own tenure starts).
  (b) **SLO dominance** — with deadlines set, the SLO engine's
      attained-SLO fraction is >= the FIFO engine's on the same trace.
  (c) **FIFO equivalence** — with no priorities or deadlines configured
      the composer-equipped engine dispatches in bitwise the same order
      as the plain FIFO engine.

All traces replay against one module-compiled 3-tenant testbed artifact;
rounds execute analytically (``execute=False``) so hundreds of requests
cost milliseconds.  Works under real hypothesis (derandomized — the
serving loop is concurrency-sensitive enough without example-order
nondeterminism) and under the deterministic ``tests/_hypo`` stand-in.
"""

import pytest

from _hypo import given, settings, st

from repro.core.deploy import CompileRequest, DeploymentSession
from repro.serve.admission import (AdmissionController, ClassPolicy,
                                   ComposerConfig, Priority, RoundComposer,
                                   RoundPlanProbe, TenantView,
                                   has_slo_signal)
from repro.serve.engine import MultiModelEngine
from repro.soc.testbed import dense_chain, two_acc_soc

N_TENANTS = 3


def make_session() -> DeploymentSession:
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain("a", [64, 64, 64]),
              dense_chain("b", [48, 48, 48]),
              dense_chain("c", [32, 32, 32])]
    return DeploymentSession(CompileRequest(
        graphs=graphs, soc=soc, patterns=pats,
        requested_tiles=4, time_budget_s=0.5))


_MC = None


def get_mc():
    """Module-memoized compiled artifact: the ``@given`` tests cannot take
    pytest fixtures (the ``_hypo`` stand-in's wrapper hides the
    signature), so they share the compile through this instead.  Every
    occupancy is precompiled so the composer's plan-store probe sees the
    same (fully warm) state whatever order the tests run in — the
    composer's choices depend on which occupancy plans are cached."""
    global _MC
    if _MC is None:
        session = make_session()
        _MC = session.compile(precompile=[[0], [1], [2], [0, 1], [0, 2],
                                          [1, 2]])
    return _MC


@pytest.fixture(scope="module")
def mc():
    return get_mc()


# ---------------------------------------------------------------------------
# Trace harness
# ---------------------------------------------------------------------------

# one trace event: (idle_rounds_before, tenant, priority, deadline_class)
# deadline_class: None = no deadline, "tight" ~ one solo makespan,
# "normal" ~ a few co-rounds, "loose" ~ the whole trace
DEADLINE_SCALES = {None: None, "tight": 1.5, "normal": 6.0, "loose": 40.0}

trace_events = st.lists(
    st.tuples(st.integers(0, 2),                    # engine rounds to burn
              st.integers(0, N_TENANTS - 1),        # tenant
              st.sampled_from(list(Priority)),      # class
              st.sampled_from([None, "tight", "normal", "loose"])),
    min_size=4, max_size=24)

no_slo_events = st.lists(
    st.tuples(st.integers(0, 2), st.integers(0, N_TENANTS - 1)),
    min_size=4, max_size=24)


def replay(engine: MultiModelEngine, events, slo: bool = True):
    """Drive one adversarial trace: submissions interleaved with serving
    rounds (the idle prefix of each event runs that many rounds first),
    then drain.  Returns the dispatch order (completed rids per round,
    flattened)."""
    base_s = engine._floor_s(0)            # deadline unit: tenant-0 floor
    order = []
    for ev in events:
        idle = ev[0]
        for _ in range(idle):
            order.extend(engine.step())
        if slo:
            _, tenant, prio, dl = ev
            scale = DEADLINE_SCALES[dl]
            engine.submit(tenant, priority=prio,
                          deadline_s=(None if scale is None
                                      else scale * base_s))
        else:
            engine.submit(ev[1])
    while engine.pending:
        order.extend(engine.step())
    return order


def slo_engine(mc, **kw) -> MultiModelEngine:
    return MultiModelEngine(mc, composer=RoundComposer(), execute=False,
                            **kw)


# ---------------------------------------------------------------------------
# (a) no starvation
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None, derandomize=True)
@given(trace_events)
def test_no_starvation_under_adversarial_traces(events):
    """Every admitted request completes, within the composer's hard bound
    of serving rounds — whatever the arrival pattern, priority mix, or
    deadline pressure."""
    mc = get_mc()
    eng = slo_engine(mc)
    replay(eng, events)
    bound = eng.composer.config.starvation_rounds
    assert eng.pending == 0
    assert len(eng.done) == len(events)
    for r in eng.done.values():
        # EDF within-class reordering stretches the FIFO bound by the
        # recorded bypass count, which is itself structurally capped at
        # starvation_rounds (an exhausted request blocks further jumps)
        assert r.edf_bypasses <= bound, (r.rid, r.edf_bypasses)
        assert r.wait_rounds <= bound * (r.depth_at_submit + 1
                                         + r.edf_bypasses), \
            (r.rid, r.tenant, r.priority, r.wait_rounds, r.depth_at_submit)
    assert eng.starvation_events() == 0


@settings(max_examples=4, deadline=None, derandomize=True)
@given(trace_events)
def test_admission_bounds_low_class_queue(events):
    """With a queue bound on LOW, at most that many LOW requests are ever
    queued; rejections are recorded, admitted+rejected == submitted."""
    mc = get_mc()
    adm = AdmissionController({Priority.LOW: ClassPolicy(max_queued=2)})
    eng = slo_engine(mc, admission=adm)
    for ev in events:
        _, tenant, prio, _ = ev
        eng.submit(tenant, priority=prio)
        assert sum(1 for q in eng.queues for r in q
                   if r.priority == Priority.LOW) <= 2
    eng.run()
    rep = eng.report()
    for p in Priority:
        cls = rep["per_class"][p.name]
        assert cls["served"] + cls["rejected"] == cls["submitted"]


# ---------------------------------------------------------------------------
# (b) SLO dominance over FIFO
# ---------------------------------------------------------------------------


def attainment(engine: MultiModelEngine):
    rep = engine.report()
    return rep["slo_attainment"], rep["per_class"]


@settings(max_examples=8, deadline=None, derandomize=True)
@given(trace_events)
def test_slo_attainment_dominates_fifo(events):
    """On the same trace, the deadline-driven composer attains at least
    the FIFO engine's SLO fraction (FIFO's all-active composition is
    always among the scored candidates, and the deadline-protective rule
    never trades a feasible deadline away)."""
    mc = get_mc()
    fifo = MultiModelEngine(mc, execute=False)
    replay(fifo, events)
    slo = slo_engine(mc)
    replay(slo, events)
    assert len(slo.done) == len(fifo.done) == len(events)
    a_fifo, _ = attainment(fifo)
    a_slo, _ = attainment(slo)
    if a_fifo is None:
        assert a_slo is None            # no deadlines in the trace at all
    else:
        assert a_slo >= a_fifo - 1e-12, (a_slo, a_fifo)


def test_slo_strictly_beats_fifo_on_contended_trace(mc):
    """The motivating scenario, pinned: HIGH tight-deadline traffic on one
    tenant contended by deadline-less bulk traffic on the others.  FIFO
    co-schedules everyone and the HIGH requests miss; the composer
    fast-paths them and attains strictly more."""
    def drive(engine):
        base_s = engine._floor_s(0)
        for _ in range(4):               # bulk backlog first
            engine.submit(1)
            engine.submit(2)
        for _ in range(4):
            engine.submit(0, priority=Priority.HIGH,
                          deadline_s=2.2 * base_s)
        engine.run()
        return engine.report()

    rep_fifo = drive(MultiModelEngine(mc, execute=False))
    rep_slo = drive(slo_engine(mc))
    high_fifo = rep_fifo["per_class"]["HIGH"]["slo_attainment"]
    high_slo = rep_slo["per_class"]["HIGH"]["slo_attainment"]
    assert high_slo > high_fifo, (high_slo, high_fifo)
    assert rep_slo["starvation_events"] == 0
    # bulk traffic still fully served (no starvation for the losers)
    assert rep_slo["served"] == rep_fifo["served"] == 12


# ---------------------------------------------------------------------------
# (c) FIFO equivalence without SLOs
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None, derandomize=True)
@given(no_slo_events)
def test_fifo_equivalence_without_slos(events):
    """A composer- and admission-equipped engine given only default-class,
    deadline-less requests dispatches in bitwise the same order as the
    plain engine — the SLO layer is inert until SLOs exist."""
    mc = get_mc()
    plain = MultiModelEngine(mc, execute=False)
    order_plain = replay(plain, events, slo=False)
    slo = MultiModelEngine(mc, composer=RoundComposer(),
                           admission=AdmissionController(), execute=False)
    order_slo = replay(slo, events, slo=False)
    assert order_plain == order_slo
    assert slo.composer.slo_rounds == 0
    assert slo.composer.fifo_rounds == slo.rounds
    # same round structure, not just the same completion order
    for key in ("rounds", "co_rounds", "subset_co_rounds", "solo_rounds",
                "solo_dispatches"):
        assert plain.report()[key] == slo.report()[key], key


# ---------------------------------------------------------------------------
# (d) EDF within-queue reordering
# ---------------------------------------------------------------------------


def test_edf_serves_earliest_winnable_deadline_first(mc):
    """Within one tenant's queue and one priority class, the earlier
    absolute deadline dispatches first even when submitted later."""
    eng = slo_engine(mc)
    base = eng._floor_s(0)
    r_loose = eng.submit(0, deadline_s=40.0 * base)
    r_tight = eng.submit(0, deadline_s=3.0 * base)
    first = eng.step()
    assert first == [r_tight]
    eng.run()
    assert eng.done[r_loose].edf_bypasses == 1
    assert eng.starvation_events() == 0


def test_edf_never_endangers_a_winnable_deadline(mc):
    """A jump is refused when the bypassed request's deadline is winnable
    but would not survive one extra wave of delay — FIFO order holds."""
    eng = slo_engine(mc)
    base = eng._floor_s(0)
    r_fragile = eng.submit(0, deadline_s=1.5 * base)   # in [floor, 2*floor)
    r_tight = eng.submit(0, deadline_s=1.2 * base)
    assert eng.step() == [r_fragile]


def test_edf_lost_cause_earns_no_jump(mc):
    """A deadline that cannot be met even if served immediately gets no
    EDF boost: the queue stays FIFO instead of sacrificing throughput
    order to a lost cause."""
    eng = slo_engine(mc)
    base = eng._floor_s(0)
    r_first = eng.submit(0)                            # deadline-less bulk
    r_lost = eng.submit(0, deadline_s=0.2 * base)      # already infeasible
    assert eng.step() == [r_first]
    eng.run()
    assert eng.done[r_lost].deadline_met is False


def test_edf_bypass_cap_restores_fifo(mc):
    """A request bypassed ``starvation_rounds`` times blocks further
    jumps over it, bounding how long EDF can delay deadline-less work."""
    eng = MultiModelEngine(mc, execute=False,
                           composer=RoundComposer(
                               ComposerConfig(starvation_rounds=2)))
    base = eng._floor_s(0)
    r0 = eng.submit(0)                                 # deadline-less
    order = []
    for _ in range(3):
        eng.submit(0, deadline_s=100.0 * base)
        order.extend(eng.step())
    assert order[:2] != [r0, r0] and r0 == order[2]    # 2 jumps, then r0
    eng.run()
    assert eng.done[r0].edf_bypasses == 2
    assert eng.starvation_events() == 0


# ---------------------------------------------------------------------------
# Composer unit behaviour (no engine, no compile)
# ---------------------------------------------------------------------------


def _probe(floors):
    return RoundPlanProbe(try_plan=lambda ids: None,
                          cycles_to_s=lambda c: c,
                          floors_s=dict(floors))


def _view(tenant, prio=Priority.NORMAL, deadline=None, wait=0, floor=1.0,
          tenure=None):
    return TenantView(tenant=tenant, priority=prio, deadline_abs_s=deadline,
                      wait_rounds=wait, depth=1, floor_s=floor,
                      head_tenure_rounds=wait if tenure is None else tenure)


def test_composer_fifo_composition_without_signal():
    comp = RoundComposer()
    views = [_view(0), _view(2), _view(1)]
    assert comp.compose(views, 0.0, _probe({i: 1.0 for i in range(3)})) \
        == [0, 1, 2]
    assert comp.fifo_rounds == 1 and comp.slo_rounds == 0


def test_composer_prefers_urgent_subset():
    """A HIGH head whose deadline only a small round can meet wins over
    the full-house composition (deferral strictly improves the predicted
    deadline outcome, so the full-set tie-break does not apply)."""
    comp = RoundComposer()
    views = [_view(0), _view(1),
             _view(2, prio=Priority.HIGH, deadline=1.2)]
    ids = comp.compose(views, 0.0, _probe({0: 1.0, 1: 1.0, 2: 1.0}))
    assert 2 in ids and len(ids) < 3


def test_composer_full_set_on_feasible_deadlines():
    """When the full composition meets every deadline, deferral cannot
    strictly improve the outcome, so FIFO's all-active round wins."""
    comp = RoundComposer()
    views = [_view(0, deadline=10.0), _view(1, prio=Priority.HIGH,
                                            deadline=10.0), _view(2)]
    ids = comp.compose(views, 0.0, _probe({0: 1.0, 1: 1.0, 2: 1.0}))
    assert ids == [0, 1, 2]


def test_composer_forces_starved_head():
    cfg = ComposerConfig(starvation_rounds=4)
    comp = RoundComposer(cfg)
    views = [_view(0, prio=Priority.HIGH, deadline=1.2),
             _view(1, prio=Priority.LOW, wait=4)]
    ids = comp.compose(views, 0.0, _probe({0: 1.0, 1: 1.0}))
    assert 1 in ids                     # starved LOW head force-included
    assert comp.forced_inclusions == 1


def test_composer_protects_feasible_deadline_of_excluded_head():
    """Candidates that would let an excluded head's still-feasible
    deadline expire during the round are discarded."""
    comp = RoundComposer()
    # tenant 1's deadline (2.5) survives a 1.0 round + its 1.0 floor, but
    # not a 2.0 round; tenant 0 is HIGH so the scorer wants {0} alone —
    # the protective rule forbids leaving 1 behind a slow candidate
    views = [_view(0, prio=Priority.HIGH, deadline=10.0, floor=2.0),
             _view(1, deadline=2.5, floor=1.0)]
    ids = comp.compose(views, 0.0, _probe({0: 2.0, 1: 1.0}))
    assert 1 in ids


def test_has_slo_signal():
    assert not has_slo_signal([_view(0), _view(1)])
    assert has_slo_signal([_view(0, prio=Priority.HIGH)])
    assert has_slo_signal([_view(0, deadline=1.0)])


def test_admission_controller_counts():
    adm = AdmissionController({Priority.LOW: ClassPolicy(max_queued=0)})
    assert adm.admit(Priority.NORMAL, {p: 0 for p in Priority})
    assert not adm.admit(Priority.LOW, {p: 0 for p in Priority})
    s = adm.stats()
    assert s["NORMAL"]["admitted"] == 1 and s["LOW"]["rejected"] == 1


def test_composer_config_validation():
    with pytest.raises(ValueError):
        ComposerConfig(starvation_rounds=0)
    with pytest.raises(ValueError):
        ComposerConfig(aging_weight=-1.0)
    with pytest.raises(ValueError):
        ComposerConfig(miss_factor=2.0)
    with pytest.raises(ValueError):
        ClassPolicy(max_queued=-1)
