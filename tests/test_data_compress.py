"""Data pipeline determinism/resume/sharding + gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.data.pipeline import DataConfig, Pipeline
from repro.optim import compress
from repro.train.step import IGNORE


def _cfg(**kw):
    base = dict(vocab=1000, seq_len=64, global_batch=4, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_determinism():
    a = next(Pipeline(_cfg()))
    b = next(Pipeline(_cfg()))
    np.testing.assert_array_equal(a["x"], b["x"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_resume_exact():
    p = Pipeline(_cfg())
    for _ in range(3):
        next(p)
    state = p.state()
    want = next(p)
    q = Pipeline.restore(_cfg(), state)
    got = next(q)
    np.testing.assert_array_equal(got["x"], want["x"])


def test_host_sharding_disjoint_and_complete():
    full = next(Pipeline(_cfg(num_hosts=1, host_index=0)))
    parts = [next(Pipeline(_cfg(num_hosts=2, host_index=i)))
             for i in range(2)]
    stacked = np.concatenate([p["x"] for p in parts], axis=0)
    np.testing.assert_array_equal(stacked, full["x"])


def test_label_shift_and_boundaries():
    p = Pipeline(_cfg())
    saw_boundary = False
    for _ in range(6):
        b = next(p)
        x, y = b["x"], b["labels"]
        # next-token property wherever no document boundary intervenes
        agree = (y[:, :-1] == x[:, 1:]) | (y[:, :-1] == IGNORE)
        assert agree.mean() > 0.99
        saw_boundary |= bool((y == IGNORE).sum() >= 1)
    assert saw_boundary                   # boundaries do get masked


def test_embed_stub_mode():
    b = next(Pipeline(_cfg(embed_dim=32)))
    assert b["x"].shape == (4, 64, 32)
    assert b["labels"].shape == (4, 64)


# ---------------------------------------------------------------- compress
def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    q, s = compress.quantize(x)
    err = jnp.max(jnp.abs(compress.dequantize(q, s) - x))
    assert float(err) <= float(s) / 2 + 1e-6


def test_compressed_psum_single_device_exact_with_feedback():
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (32, 8))}
    e = compress.init_error(g)

    @jax.jit
    def run(g, e):
        from jax.experimental.shard_map import shard_map
        f = shard_map(
            lambda gg, ee: compress.compressed_psum(gg, ee, "data"),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
        return f(g, e)

    avg, e2 = run(g, e)
    # single replica: avg = dequant(quant(g)); error feedback holds residual
    resid = g["w"].astype(jnp.float32) - avg["w"].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(e2["w"]), np.asarray(resid),
                               atol=1e-6)
    # error feedback property: avg2 = dequant(quant(g + e)) ~ 2g - avg, so
    # the running mean of the two rounds recovers g to quantization scale
    avg2, _ = run(g, e2)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    two_step = (np.asarray(avg["w"], np.float32)
                + np.asarray(avg2["w"], np.float32)) / 2
    np.testing.assert_allclose(two_step, np.asarray(g["w"], np.float32),
                               atol=2 * scale)
