"""Fleet-scale serving: contention-aware placement, routing, failure
handling, and cross-SoC migration correctness.

Uses the two-accelerator contention testbed (small dense chains) so the
whole module compiles in seconds; the fleet artifact cache is shared per
module-scoped fixture."""

import numpy as np
import pytest

from repro.core.runtime import execute_plan, init_inputs
from repro.fleet import (FailureEvent, Fleet, FleetConfig, FleetRebalancer,
                         FleetRouter, Placement, place_contention_aware,
                         place_random, place_round_robin, replay_open_loop,
                         transplant_solutions)
from repro.serve.admission import Priority
from repro.soc.testbed import (FORCED_DMA_BW, FORCED_L2_KIB, dense_chain,
                               two_acc_soc)


def _factory():
    return two_acc_soc(FORCED_L2_KIB, FORCED_DMA_BW)


def _graphs():
    # "a" is the heavy contention-prone class; "b"/"c" lighter
    return [dense_chain("a", [64] * 5), dense_chain("b", [48] * 4),
            dense_chain("c", [32] * 4)]


def _config(**kw):
    base = dict(soc_factory=_factory, n_socs=3, capacity=2,
                requested_tiles=4, time_budget_s=0.25,
                joint_time_budget_s=0.4, lazy_joint_time_budget_s=0.25,
                incremental_time_budget_s=0.25)
    base.update(kw)
    return FleetConfig(**base)


@pytest.fixture(scope="module")
def fleet3():
    """3 SoCs x capacity 2, three classes, analytic engines."""
    return Fleet(_config(), _graphs())


TENANTS = ["a", "a", "b", "b", "c"]


# ---------------------------------------------------------------------------
# (a) placement
# ---------------------------------------------------------------------------


def _assert_feasible(p, tenants, n_socs, capacity):
    assert len(p.assignment) == n_socs
    assert sorted(p.tenants()) == sorted(tenants)
    for names in p.assignment:
        assert len(names) <= capacity
        assert len(set(names)) == len(names)       # replicas never co-reside


def test_placements_feasible(fleet3):
    for p in (place_round_robin(TENANTS, 3, 2, fleet3.contention),
              place_random(TENANTS, 3, 2, fleet3.contention, seed=7),
              place_contention_aware(TENANTS, 3, 2, fleet3.contention)):
        _assert_feasible(p, TENANTS, 3, 2)
        assert p.objective_s == max(p.predicted_round_s)
        # a replica never serves faster than alone -> dilution >= 1
        assert p.capacity_ratio >= 1.0 - 1e-12
        # tenants placed + nonzero demand -> nonzero bottleneck util
        assert p.max_rho > 0.0


def test_contention_aware_never_worse_than_baselines(fleet3):
    """The hybrid ships the best candidate on its own objective
    (bottleneck utilization under balanced demand), and the round-robin
    deal is one of its descent starts — so it can never score worse
    than that baseline, and on this small instance it dominates random
    seeds too."""
    ca = place_contention_aware(TENANTS, 3, 2, fleet3.contention)
    rr = place_round_robin(TENANTS, 3, 2, fleet3.contention)
    assert ca.max_rho <= rr.max_rho + 1e-9
    for seed in range(5):
        rd = place_random(TENANTS, 3, 2, fleet3.contention, seed=seed)
        assert ca.max_rho <= rd.max_rho + 1e-9
    # the CP polish + local search report what they did
    assert ca.stats["cp"] in ("solved", "skipped", "infeasible")
    assert ca.stats["search_iters"] >= 1


def test_capacity_ratio_penalizes_light_under_heavy(fleet3):
    """Parking the light class 'c' under the heavy class 'a' dilutes
    'c' capacity by about alone_a / alone_c even though the pair's
    round excess is small — the failure mode the round-makespan
    objective cannot see."""
    from repro.fleet import capacity_ratio
    c = fleet3.contention
    packed = [["a", "c"], ["b"], []]       # c queues behind a
    apart = [["a"], ["b", "c"], []]        # c next to the lighter b
    assert capacity_ratio(packed, c) > capacity_ratio(apart, c)
    # singles only -> no dilution at all
    assert capacity_ratio([["a"], ["b"], ["c"]], c) == \
        pytest.approx(1.0)


def test_utilization_models_round_sharing(fleet3):
    """soc_utilization mirrors engine round composition: solo rounds
    for the rate excess, joint rounds for the overlap, so a co-resident
    with spare rate rides joint rounds at the pair's marginal cost."""
    from repro.fleet import balanced_utilization, soc_utilization
    c = fleet3.contention
    # single class: rho = rate x alone
    assert soc_utilization(["a"], {"a": 2.0}, c) == \
        pytest.approx(2.0 * c.alone_s("a"))
    # equal rates: every round is a joint round
    assert soc_utilization(["a", "b"], {"a": 1.0, "b": 1.0}, c) == \
        pytest.approx(c.pair_s("a", "b"))
    # a light rider costs only the pair's excess over the busy class
    r0 = soc_utilization(["a", "b"], {"a": 2.0, "b": 0.0}, c)
    r1 = soc_utilization(["a", "b"], {"a": 2.0, "b": 1.0}, c)
    assert r1 - r0 == pytest.approx(c.pair_s("a", "b")
                                    - c.alone_s("a"))
    # balancing splits a replicated class across its hosts
    lam = 1.0 / c.alone_s("a")
    max_rho, _, split = balanced_utilization([["a"], ["a"], []], c,
                                             {"a": lam})
    assert max_rho == pytest.approx(0.5, rel=0.05)
    # the returned split is the routing table realizing that rho
    assert sum(s.get("a", 0.0) for s in split) == pytest.approx(lam)
    assert split[2] == {}


def test_contention_model_pair_costs(fleet3):
    c = fleet3.contention
    # co-residency can't be cheaper than the heavier member alone
    assert c.pair_s("a", "b") >= max(c.alone_s("a"), c.alone_s("b")) - 1e-12
    assert c.excess_s("a", "b") >= 0.0
    # predictor is exact at <=2 tenants and monotone in membership
    assert c.predict_round_s(["a"]) == pytest.approx(c.alone_s("a"))
    assert c.predict_round_s(["a", "b"]) == pytest.approx(c.pair_s("a", "b"))
    assert c.predict_round_s(["a", "b"]) >= c.predict_round_s(["a"]) - 1e-12


def test_placement_replica_needs_distinct_socs(fleet3):
    with pytest.raises(ValueError):
        place_round_robin(["a", "a", "a", "a"], 3, 2, None)
    with pytest.raises(ValueError):
        place_contention_aware(["a"] * 4, 3, 2, fleet3.contention)


# ---------------------------------------------------------------------------
# (b) routing
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def routed_fleet():
    """A fresh fleet with a replicated class for routing tests; subset
    occupancies are NOT precompiled (singles only), so on the
    capacity-3 SoC a *pair* occupancy is a true subset and probes
    cold (on a capacity-2 SoC every pair is the always-warm full
    house)."""
    fleet = Fleet(_config(precompile="singles", capacity=3), _graphs())
    fleet.apply_placement(Placement(
        assignment=[("a", "b", "c"), ("a", "c"), ()], method="manual"))
    return fleet


def test_router_spreads_replicated_class_by_backlog(routed_fleet):
    router = FleetRouter(routed_fleet)
    for _ in range(6):
        router.submit("a", arrival_s=0.0)
    stats = router.audit()
    # without stepping, backlog accrues on the picked SoC and pushes the
    # next request to the other replica — both hosts end up with work
    assert stats["routed_per_soc"].get(0, 0) > 0
    assert stats["routed_per_soc"].get(1, 0) > 0
    assert stats["submitted"] == 6 and stats["dropped"] == 0
    for inst in routed_fleet.live():
        if inst.engine is not None:
            inst.engine.run()


def test_router_warm_and_cold_probes(routed_fleet):
    router = FleetRouter(routed_fleet)
    # singleton occupancies are precompiled -> warm route (ties break
    # to SoC0, which hosts every class)
    router.submit("c", arrival_s=100.0)
    assert router.warm_routes == 1 and router.cold_routes == 0
    # "b" is hosted only on SoC0, where "c" is already queued: the
    # {b, c} occupancy is an unprecompiled subset -> cold route
    router.submit("b", arrival_s=100.0)
    assert router.cold_routes == 1
    for inst in routed_fleet.live():
        if inst.engine is not None:
            inst.engine.run()


def test_router_rejects_unhosted_class(routed_fleet):
    router = FleetRouter(routed_fleet)
    with pytest.raises(RuntimeError):
        router.submit("nope", arrival_s=0.0)


def test_router_paces_toward_demand_split(routed_fleet):
    # a lopsided split for the replicated class: the router's deficit
    # penalty should hold dispatch near the 1:3 quota even though the
    # myopic score alone would alternate hosts
    split = [{"a": 0.25}, {"a": 0.75}, {}]
    router = FleetRouter(routed_fleet, split=split)
    for _ in range(20):
        router.submit("a", arrival_s=0.0)
    per_soc = router.audit()["routed_per_soc"]
    assert per_soc.get(1, 0) > per_soc.get(0, 0)
    assert per_soc.get(1, 0) >= 12          # ~15 expected at quota
    for inst in routed_fleet.live():
        if inst.engine is not None:
            inst.engine.run()


# ---------------------------------------------------------------------------
# (c) failure handling: zero drops, requeue, analyzer-clean migration
# ---------------------------------------------------------------------------


def test_mid_trace_failure_drops_nothing(fleet3):
    fleet = Fleet(_config(), _graphs())
    # 4 tenants in 6 slots: survivors keep spare capacity for the
    # migration (a full fleet has nowhere to re-host and raises)
    tenants = ["a", "a", "b", "c"]
    fleet.apply_placement(place_contention_aware(tenants, 3, 2,
                                                 fleet.contention))
    router = FleetRouter(fleet)
    reb = FleetRebalancer(fleet, router)
    # dense arrivals so the failing SoC has queued work at the event;
    # fail the SoC hosting "c" — the single-replica class, so the
    # failure forces a real migration (a/b replicas keep serving)
    victim = fleet.hosts_of("c")[0].soc_id
    classes = ["a", "b", "c"]
    trace = [(i * 1e-4, classes[i % 3],
              Priority.HIGH if i % 4 == 0 else Priority.NORMAL,
              1.0 if i % 4 == 0 else None) for i in range(40)]
    failures = [FailureEvent(at_s=5e-4, soc_id=victim, kind="fail")]
    summary = replay_open_loop(fleet, router, trace, failures=failures,
                               rebalancer=reb)
    audit = summary["router"]
    assert audit["dropped"] == 0
    assert audit["queued"] == 0
    assert audit["served"] == audit["submitted"] - audit["rejected"]
    assert summary["served"] >= 40            # requeues re-serve elsewhere
    reb_stats = summary["rebalance"]
    assert reb_stats["failures"] == 1
    assert reb_stats["migrations"] >= 1
    assert reb_stats["analyzer_errors"] == 0
    assert len(reb_stats["recovery_s"]) == 1
    assert reb_stats["recovery_s"][0] >= 0.0
    assert fleet.instances[victim].failed
    assert not fleet.instances[victim].accepting
    # every class is still served somewhere
    for name in classes:
        assert fleet.hosts_of(name), f"class {name} orphaned"


def test_drain_is_graceful(fleet3):
    fleet = Fleet(_config(), _graphs())
    fleet.apply_placement(Placement(
        assignment=[("a",), ("b", "c"), ()], method="manual"))
    router = FleetRouter(fleet)
    reb = FleetRebalancer(fleet, router)
    for i in range(4):
        router.submit("a", arrival_s=i * 1e-4)
    recs = reb.drain(0, at_s=1e-3)
    # the drained SoC finished its own queue (nothing requeued) ...
    assert fleet.instances[0].engine.pending == 0
    assert router.requeued == 0
    # ... and its class was re-hosted on a survivor
    assert [r.class_name for r in recs] == ["a"]
    assert fleet.hosts_of("a")
    assert router.audit()["dropped"] == 0


# ---------------------------------------------------------------------------
# (d) cross-SoC migration correctness: bitwise numerics + analyzer-clean
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def exec_fleet():
    """2 SoCs, numeric execution on, class 'a' alone on SoC0 and 'b'
    alone on SoC1 — failing SoC0 forces a real (a, b) migration
    compile."""
    fleet = Fleet(_config(n_socs=2, capacity=2, execute=True,
                          precompile="singles"),
                  _graphs()[:2])
    fleet.apply_placement(Placement(
        assignment=[("a",), ("b",)], method="manual"))
    return fleet


def test_migration_preserves_numerics_bitwise(exec_fleet):
    fleet = exec_fleet
    router = FleetRouter(fleet)
    reb = FleetRebalancer(fleet, router)
    g_a = fleet.cache.classes["a"]
    inputs = init_inputs(g_a, seed=123)
    params = fleet.cache.params_for("a")

    # serve one request on the original host, capture its outputs
    src = fleet.instances[0]
    rid_before = src.engine.submit("a", inputs=dict(inputs))
    src.engine.run()
    out_before = src.engine.results[rid_before]

    # kill SoC0 -> 'a' migrates onto SoC1 next to 'b'
    recs = reb.fail(0, at_s=1.0)
    assert [r.class_name for r in recs] == ["a"]
    dst = fleet.instances[recs[0].dst_soc]
    assert dst.hosts("a") and dst.hosts("b")
    # the destination plans carry zero analyzer ERROR diagnostics
    assert recs[0].analyzer_errors == 0
    assert dst.mc.session.analysis_stats()["errors"] == 0

    # serve the SAME inputs on the destination
    rid_after = dst.engine.submit("a", inputs=dict(inputs))
    dst.engine.run()
    out_after = dst.engine.results[rid_after]

    # bitwise: migration must not change a single ULP
    assert out_before.keys() == out_after.keys()
    for t in out_before:
        assert np.array_equal(np.asarray(out_before[t]),
                              np.asarray(out_after[t])), t

    # and both match the session's single-model reference schedule for
    # the tiling actually used by the serving occupancy
    idx = dst.engine.resolve("a")
    plan = dst.mc.plan_for([idx])
    ref = dst.mc.session.reference_plan(idx, plan.tenants[0])
    want = execute_plan(ref, inputs, params)
    for t in want:
        assert np.array_equal(np.asarray(out_after[t]),
                              np.asarray(want[t])), t


def test_migration_warm_starts_from_sidecar(exec_fleet):
    """The (a, b) migration build was seeded from the donated solutions
    sidecars (the failed SoC's and the destination's own)."""
    fleet = exec_fleet
    info = fleet.cache.build_info(("a", "b"))
    assert info is not None
    assert info["seeded_occupancies"] >= 1


def test_transplant_solutions_remaps_by_name(exec_fleet):
    """Direct transplant: singleton solutions move across sessions with
    indices remapped through class names."""
    fleet = exec_fleet
    src = fleet.cache.mc_for(("a",)).session
    dst = fleet.cache.mc_for(("a", "b")).session
    assert transplant_solutions(src, dst) >= 1
    a_dst = [g.name for g in dst.request.graphs].index("a")
    assert dst.store.solutions([a_dst]) is not None


# ---------------------------------------------------------------------------
# (e) cross-clock SLO preservation on requeue
# ---------------------------------------------------------------------------


def test_requeue_preserves_absolute_deadline_across_clocks():
    """Satellite regression: a deadlined request migrated between
    engines whose analytic clocks disagree must keep its ORIGINAL
    absolute deadline.  The old requeue path re-derived a relative
    deadline against the rebalance timestamp and let the destination
    engine re-anchor it on its own clock — every clock disagreement
    drifted the SLO, and a second migration compounded it."""
    fleet = Fleet(_config(), _graphs())
    fleet.apply_placement(Placement(
        assignment=[("a", "b"), ("a",), ()], method="manual"))
    router = FleetRouter(fleet)
    reb = FleetRebalancer(fleet, router)
    src = fleet.hosts_of("b")[0]
    # advance the source engine's clock well past any survivor's
    for _ in range(4):
        router.submit("b", arrival_s=0.0)
    src.engine.run()
    now = src.engine.clock_s
    assert now > 0.0
    dst_before = fleet.hosts_of("a")[-1]
    assert dst_before.soc_id != src.soc_id
    assert dst_before.engine.clock_s < now      # the clocks disagree
    # a deadlined request queues on the advanced-clock engine
    router.submit("b", deadline_s=5.0, arrival_s=now)
    b_idx = list(src.classes).index("b")
    queued = src.engine.queues[b_idx][0]
    abs0 = queued.deadline_abs_s
    assert abs0 == pytest.approx(now + 5.0)
    # fail the source: "b" re-hosts on a survivor, the queued request
    # requeues through the router with its absolute deadline verbatim
    reb.fail(src.soc_id, at_s=now)
    new_host = fleet.hosts_of("b")[0]
    assert new_host.soc_id != src.soc_id
    new_idx = list(new_host.classes).index("b")
    migrated = new_host.engine.queues[new_idx][0]
    assert migrated.deadline_abs_override_s == pytest.approx(abs0)
    assert migrated.deadline_abs_s == pytest.approx(abs0)
    # the override is load-bearing: the destination could NOT have
    # reconstructed abs0 from its own clock and a relative deadline
    assert migrated.deadline_s is None
    assert migrated.submit_s + 5.0 != pytest.approx(abs0) or \
        migrated.submit_s == pytest.approx(now)
    # ... and the SLO verdict is judged against the original deadline
    new_host.engine.run()
    done = new_host.engine.done[migrated.rid]
    assert done.deadline_met == (done.finish_s <= abs0)
    assert router.audit()["dropped"] == 0
