"""Async background plan compiles: the serving engine never stalls on a
``plan_for`` miss.

Deterministic fake-clock tests drive a :class:`BackgroundCompiler` built
with ``start=False`` and pump it by hand (``run_pending``), so "the
background compile lands" is an explicit, reproducible event on the
engine's analytic clock — no threads, no sleeps.  The contract under
test:

  * a miss at an unseen occupancy serves the compile-alone concat floor
    *immediately* (one round, cost = sum of the members' compile-alone
    makespans — within 1.1x of the floor by construction, the acceptance
    bound) and enqueues exactly one compile job;
  * once the compile lands, the next round at that occupancy dispatches
    the real subset co-schedule, which beats or ties the floor (the
    floor is a hard candidate inside ``_compile_subset``);
  * numerics are bitwise against ``session.reference_plan`` on *both*
    sides of the swap — the floor round over the compile-alone tilings,
    the swapped round over whatever tilings the subset plan chose;
  * ``DeploymentSession.submit_compile`` compiles each occupancy exactly
    once under concurrent misses (the only test here that uses real
    threads, plus one end-to-end run with the worker thread on).
"""

import threading

import numpy as np
import pytest

from repro.core.deploy import CompileRequest, DeploymentSession
from repro.core.runtime import execute_plan, init_inputs
from repro.core.schedule import validate_multi_schedule
from repro.serve.compiler_thread import BackgroundCompiler
from repro.serve.engine import MultiModelEngine
from repro.soc.testbed import dense_chain, two_acc_soc


def make_session() -> DeploymentSession:
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain("a", [64, 64, 64]),
              dense_chain("b", [48, 48, 48]),
              dense_chain("c", [32, 32, 32])]
    return DeploymentSession(CompileRequest(
        graphs=graphs, soc=soc, patterns=pats,
        requested_tiles=4, time_budget_s=0.5))


@pytest.fixture(scope="module")
def session():
    s = make_session()
    s.compile()
    return s


def floor_cycles(mc, ids):
    return sum(mc.singles[i].plan.makespan for i in ids)


# ---------------------------------------------------------------------------
# Fake-clock floor -> swap (occupancy [0, 1])
# ---------------------------------------------------------------------------


def test_floor_immediately_then_swap_after_compile_lands(session):
    """The deterministic swap story, on one engine: miss -> floor round
    now, pump the compiler, hit -> subset co-round; numerics bitwise vs
    the session's reference plans on both sides of the swap."""
    mc = session.compile()
    bg = BackgroundCompiler(session, start=False)
    eng = MultiModelEngine(mc, async_compile=bg, seed=3)
    assert session.try_plan_for([0, 1]) is None     # genuinely unseen

    xs = {i: init_inputs(mc.graphs[i], 30 + i) for i in (0, 1)}
    rids = {i: eng.submit(i, inputs=xs[i]) for i in (0, 1)}
    done = eng.step()                   # miss: floor round, no stall
    assert sorted(done) == sorted(rids.values())
    assert eng.floor_rounds == 1 and eng.co_rounds == 0
    assert bg.pending == 1              # one compile job enqueued
    assert session.try_plan_for([0, 1]) is None     # not compiled yet
    # the floor round costs exactly the compile-alone concat
    floor = floor_cycles(mc, [0, 1])
    assert eng.busy_cycles == pytest.approx(floor)
    for i in (0, 1):
        r = eng.done[rids[i]]
        assert r.served_on_floor and not r.co_scheduled
        # bitwise vs the reference plan over the compile-alone tiling
        ref = session.reference_plan(i, mc.singles[i].tiled)
        want = execute_plan(ref, xs[i], eng.params[i])
        for t in mc.graphs[i].outputs:
            assert np.array_equal(np.asarray(want[t]),
                                  np.asarray(eng.results[rids[i]][t]))

    assert bg.run_pending() == 1        # the background compile "lands"
    assert bg.compiled == 1 and bg.pending == 0
    sub = session.try_plan_for([0, 1])
    assert sub is not None
    assert validate_multi_schedule(sub) == []
    assert sub.makespan <= floor + 1e-6     # floor is a hard candidate

    rids2 = {i: eng.submit(i, inputs=xs[i]) for i in (0, 1)}
    eng.step()                          # hit: the real subset co-round
    assert eng.co_rounds == 1 and eng.subset_co_rounds == 1
    assert eng.floor_rounds == 1        # no new floor round
    for pos, i in enumerate((0, 1)):
        r = eng.done[rids2[i]]
        assert r.co_scheduled and not r.served_on_floor
        ref = session.reference_plan(i, sub.tenants[pos])
        want = execute_plan(ref, xs[i], eng.params[i])
        for t in mc.graphs[i].outputs:
            assert np.array_equal(np.asarray(want[t]),
                                  np.asarray(eng.results[rids2[i]][t]))


def test_first_round_latency_within_floor_bound(session):
    """The acceptance criterion: first-round latency at an unseen
    occupancy <= 1.1x the compile-alone concat floor (no joint-solve
    stall on the dispatch path)."""
    mc = session.compile()
    bg = BackgroundCompiler(session, start=False)
    eng = MultiModelEngine(mc, async_compile=bg, execute=False)
    assert session.try_plan_for([0, 2]) is None
    eng.submit(0)
    eng.submit(2)
    eng.step()
    floor_ms = mc.soc.cycles_to_ms(floor_cycles(mc, [0, 2]))
    worst = max(r.latency_ms for r in eng.done.values())
    assert worst <= 1.1 * floor_ms
    assert eng.clock_s * 1e3 <= 1.1 * floor_ms


# ---------------------------------------------------------------------------
# submit_compile: exactly once under concurrent misses ([1, 2])
# ---------------------------------------------------------------------------


def test_submit_compile_exactly_once_under_concurrency(session):
    """N threads race submit_compile on the same unseen occupancy: one
    compiles, the rest bounce off the in-flight set; the store gains one
    co-plan and every thread sees the same cached object afterwards."""
    assert session.try_plan_for([1, 2]) is None
    before = session.store.stats()
    lazy_before = session.lazy_compiles
    n = 6
    barrier = threading.Barrier(n)
    results = [None] * n

    def race(k):
        barrier.wait()
        results[k] = session.submit_compile([1, 2])

    threads = [threading.Thread(target=race, args=(k,)) for k in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert sum(1 for r in results if r) == 1        # exactly one compiled
    assert session.lazy_compiles == lazy_before + 1
    after = session.store.stats()
    assert after["co_plans"] == before["co_plans"] + 1
    plan = session.try_plan_for([1, 2])
    assert plan is not None and plan is session.try_plan_for([2, 1])
    # already cached: further submits are no-ops
    assert session.submit_compile([1, 2]) is False


def test_submit_compile_full_house_is_noop(session):
    assert session.submit_compile([0, 1, 2]) is False
    assert session.try_plan_for([0, 1, 2]) is session.compile().plan


def test_try_plan_for_never_compiles(session):
    before = session.store.stats()["compiles"]
    session.try_plan_for([0])           # probe only: a miss must not compile
    assert session.store.stats()["compiles"] == before


def test_background_compiler_dedupes_submits(session):
    bg = BackgroundCompiler(session, start=False)
    first = bg.submit([0])
    again = bg.submit([0])
    if first:                           # occupancy was unseen
        assert not again and bg.duplicates == 1
        bg.run_pending()
        assert bg.compiled == 1
    # cached now: submit bounces without queueing
    assert not bg.submit([0])
    assert bg.pending == 0


def test_lazy_budget_validation():
    soc, pats = two_acc_soc(64, 8.0)
    g = dense_chain("a", [32, 32])
    with pytest.raises(ValueError):
        CompileRequest(graphs=[g], soc=soc, patterns=pats,
                       lazy_joint_time_budget_s=0.0)
    req = CompileRequest(graphs=[g], soc=soc, patterns=pats)
    assert req.lazy_joint_time_budget_s < req.joint_time_budget_s


# ---------------------------------------------------------------------------
# End-to-end with the worker thread on (fresh session)
# ---------------------------------------------------------------------------


def test_threaded_compiler_end_to_end():
    """With the real worker thread, a serving burst at an unseen
    occupancy floors first, and after the compiler drains the engine
    swaps to subset co-rounds — same invariants as the fake-clock test,
    minus the determinism of *when* the swap lands."""
    session = make_session()
    mc = session.compile()
    eng = MultiModelEngine(mc, async_compile=True, execute=False)
    assert eng.compiler is not None and eng.compiler.running
    try:
        eng.submit(1)
        eng.submit(2)
        eng.step()
        assert eng.floor_rounds == 1
        assert eng.compiler.drain(timeout_s=120.0)
        assert eng.compiler.errors == []
        assert session.try_plan_for([1, 2]) is not None
        eng.submit(1)
        eng.submit(2)
        eng.step()
        assert eng.co_rounds == 1 and eng.floor_rounds == 1
        rep = eng.report()
        assert rep["async_compiler"]["compiled"] == rep["async_compiler"][
            "submitted"] == 1
        assert rep["rounds"] == rep["co_rounds"] + rep["solo_rounds"] + \
            rep["fallback_rounds"] + rep["floor_rounds"]
    finally:
        eng.compiler.stop()
    assert not eng.compiler.running
