"""Serving engine: continuous batching, determinism at T=0, cache reuse."""

import jax
import pytest

from repro.configs import registry
from repro.models.api import get_model
from repro.serve.engine import Engine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def engine():
    cfg = registry.get_smoke_config("internlm2-1.8b")
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    return Engine(cfg, params, max_seq=96, temperature=0.0)


def test_engine_drains_queue(engine):
    rids = [engine.submit([1, 2, 3, 4], max_new=6) for _ in range(5)]
    out = engine.run(batch_size=2)
    assert set(out) == set(rids)
    assert all(1 <= len(v) <= 6 for v in out.values())


def test_greedy_decode_deterministic(engine):
    r1 = engine.submit([5, 6, 7], max_new=8)
    o1 = engine.run()[r1]
    r2 = engine.submit([5, 6, 7], max_new=8)
    o2 = engine.run()[r2]
    assert o1 == o2


def test_prefix_consistency(engine):
    """Generations from the same prompt with different max_new share the
    prefix (greedy decoding is causal)."""
    ra = engine.submit([9, 10, 11], max_new=4)
    oa = engine.run()[ra]
    rb = engine.submit([9, 10, 11], max_new=8)
    ob = engine.run()[rb]
    assert ob[: len(oa)] == oa
