"""Multi-tenant engine: report invariants under a seeded mixed-class
soak and the per-tenant request batching of co-round slots.  (The old
single-model token-loop ``Engine`` was retired by the shape-bucket
rework — LM serving now goes through ``MultiModelEngine`` as bucketed
requests; see ``tests/test_shape_buckets.py``.)"""

import random

import pytest


@pytest.fixture(scope="module")
def multi_mc():
    from repro.core.deploy import CompileRequest, DeploymentSession
    from repro.soc.testbed import dense_chain, two_acc_soc
    soc, pats = two_acc_soc(64, 8.0)
    graphs = [dense_chain("a", [64, 64, 64]),
              dense_chain("b", [48, 48, 48]),
              dense_chain("c", [32, 32, 32])]
    return DeploymentSession(CompileRequest(
        graphs=graphs, soc=soc, patterns=pats,
        requested_tiles=4, time_budget_s=0.5)).compile()


def test_mixed_class_soak_report_invariants(multi_mc):
    """Seeded soak: >= 200 mixed-class requests over 3 tenants with
    arrivals and departures (idle service rounds drain queues between
    bursts).  The engine's report must keep its books straight:

      * per-class served + rejected == submitted, for every class and in
        aggregate;
      * round decomposition: co_rounds + solo_rounds + fallback_rounds +
        floor_rounds == rounds (subset co-rounds are a sub-count of
        co_rounds);
      * no negative latencies, waits, or clocks anywhere.
    """
    from repro.serve.admission import (AdmissionController, ClassPolicy,
                                       Priority, RoundComposer)
    from repro.serve.engine import MultiModelEngine
    rng = random.Random(1234)
    adm = AdmissionController({Priority.LOW: ClassPolicy(max_queued=6)})
    eng = MultiModelEngine(multi_mc, composer=RoundComposer(),
                           admission=adm, execute=False)
    n_submitted = 0
    base_s = eng._floor_s(0)
    for burst in range(40):
        for _ in range(rng.randint(2, 8)):           # arrivals
            prio = rng.choice(list(Priority))
            dl = rng.choice([None, 2.0 * base_s, 8.0 * base_s,
                             40.0 * base_s])
            eng.submit(rng.randrange(3), priority=prio, deadline_s=dl)
            n_submitted += 1
        for _ in range(rng.randint(0, 3)):           # departures
            eng.step()
    eng.run()
    assert n_submitted >= 200
    rep = eng.report()

    # class accounting closes
    per_class = rep["per_class"]
    assert sum(c["submitted"] for c in per_class.values()) == n_submitted
    for name, c in per_class.items():
        assert c["served"] + c["rejected"] == c["submitted"], name
        assert c["p99_e2e_ms"] >= c["p50_e2e_ms"] >= 0.0, name
        assert c["max_wait_rounds"] >= 0, name
    assert rep["served"] + rep["rejected"] == n_submitted
    assert rep["served"] == len(eng.done)

    # round decomposition closes
    assert rep["rounds"] == rep["co_rounds"] + rep["solo_rounds"] + \
        rep["fallback_rounds"] + rep["floor_rounds"]
    assert rep["subset_co_rounds"] <= rep["co_rounds"]
    assert rep["fallback_rounds"] == 0      # session-backed artifact

    # no negative latencies / waits / clocks
    for r in eng.done.values():
        assert r.latency_ms >= 0.0
        assert r.e2e_latency_ms >= -1e-9
        assert r.wait_rounds >= 0
        assert r.finish_s >= r.submit_s - 1e-12
    assert rep["clock_s"] >= 0.0 and rep["throughput_inf_per_s"] > 0.0
    assert rep["starvation_events"] == 0


def test_batched_co_round_slots_beat_unbatched_on_bursty_trace():
    """max_batch > 1 drains bursts in back-to-back waves inside the
    round; consecutive waves re-running the same plan pay the weights-
    resident repeat cost, so aggregate throughput on a bursty trace is
    pinned >= the unbatched engine (strictly better whenever the plan
    has parameter-load DMA traffic to save — the forced-contention mix
    does)."""
    from repro.core.api import compile_multi
    from repro.serve.engine import MultiModelEngine
    from repro.soc.testbed import forced_contention_setup
    soc, pats, graphs = forced_contention_setup()
    mc = compile_multi(graphs, soc, pats, requested_tiles=8,
                       time_budget_s=0.5)

    def bursty(engine):
        for _ in range(4):                       # a burst per tenant
            engine.submit(0)
            engine.submit(1)
        engine.run()
        return engine.report()

    rep_un = bursty(MultiModelEngine(mc, execute=False, max_batch=1))
    rep_b = bursty(MultiModelEngine(mc, execute=False, max_batch=4))
    assert rep_b["served"] == rep_un["served"] == 8
    assert rep_b["throughput_inf_per_s"] >= rep_un["throughput_inf_per_s"]
    # the repeat discount actually engaged and stayed physical
    assert rep_b["batched_repeat_rounds"] > 0
    assert rep_b["throughput_inf_per_s"] > rep_un["throughput_inf_per_s"]
    eng = MultiModelEngine(mc, execute=False)
    assert eng._repeat_cycles(mc.plan) <= mc.plan.makespan
    assert eng._repeat_cycles(mc.plan) >= max(
        b for r, b in mc.plan.busy.items() if r != "dma")


def test_batched_waves_keep_fifo_order_and_outputs(multi_mc):
    """Batched dispatch pops each tenant's queue in FIFO order and the
    per-wave numerics equal the unbatched engine's for the same inputs."""
    import numpy as np
    from repro.core.runtime import init_inputs
    from repro.serve.engine import MultiModelEngine
    xs = [init_inputs(multi_mc.graphs[0], 70 + k) for k in range(3)]
    ref = MultiModelEngine(multi_mc, seed=11)
    got = MultiModelEngine(multi_mc, seed=11, max_batch=3)
    r_ref = [ref.submit(0, inputs=x) for x in xs]
    r_got = [got.submit(0, inputs=x) for x in xs]
    ref.run()
    got.step()                                   # ONE step drains the burst
    assert got.pending == 0 and got.rounds == 1 + 2  # 3 waves = 3 rounds
    for a, b in zip(r_ref, r_got):
        ra, rb = ref.done[a], got.done[b]
        assert ra.tenant == rb.tenant
        for t in multi_mc.graphs[0].outputs:
            assert np.array_equal(np.asarray(ref.results[a][t]),
                                  np.asarray(got.results[b][t]))
