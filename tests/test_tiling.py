"""Stage-1 tile-centric optimization: Eq. (1) conservation + mode corners."""

import pytest

from repro.core.api import compile_model
from repro.core.tiling import conservation_ok, optimize_tiling
from repro.models import edge
from repro.soc.carfield import carfield_patterns, carfield_soc

# excluded from the fast CI lane (-m "not slow")
pytestmark = pytest.mark.slow

SOC = carfield_soc()
PATS = carfield_patterns()


@pytest.mark.parametrize("model", ["autoencoder", "ds_cnn", "resnet",
                                   "resnet50_block", "transformer_block"])
@pytest.mark.parametrize("mode", ["tvm", "match", "matcha_nt", "matcha"])
def test_tile_conservation(model, mode):
    g = edge.ALL_MODELS[model]()
    sol = optimize_tiling(g, SOC, PATS, mode=mode, requested_tiles=8,
                          time_budget_s=2.0)
    assert conservation_ok(g, sol), f"Eq.(1) violated for {model}/{mode}"


@pytest.mark.parametrize("mode", ["tvm", "match", "matcha_nt"])
def test_all_or_nothing_modes(mode):
    g = edge.autoencoder()
    sol = optimize_tiling(g, SOC, PATS, mode=mode, requested_tiles=8,
                          time_budget_s=2.0)
    for a in sol.assignments:
        T = sol.tiles_per_op[a.match.ops[0]]
        assert a.tiles == T, "all-or-nothing mode produced a partial match"


def test_tvm_mode_host_only():
    g = edge.ds_cnn()
    sol = optimize_tiling(g, SOC, PATS, mode="tvm", requested_tiles=1,
                          time_budget_s=2.0)
    for a in sol.assignments:
        assert a.match.pattern.device == SOC.host.name


def test_mode_ordering_autoencoder():
    """matcha <= matcha_nt <= match <= tvm on the exact stage-2 model."""
    g = edge.autoencoder()
    spans = {}
    for mode in ("tvm", "match", "matcha_nt", "matcha"):
        spans[mode] = compile_model(g, SOC, PATS, mode=mode,
                                    time_budget_s=2.0).makespan_cycles
    assert spans["matcha"] <= spans["matcha_nt"] + 1e-6
    assert spans["matcha_nt"] <= spans["match"] + 1e-6
    assert spans["match"] <= spans["tvm"] + 1e-6


def test_matcha_beats_match_on_autoencoder():
    """Paper Table 2: -33.3% on the AutoEncoder (we accept >= 25%)."""
    g = edge.autoencoder()
    m = compile_model(g, SOC, PATS, mode="match",
                      time_budget_s=2.0).makespan_cycles
    a = compile_model(g, SOC, PATS, mode="matcha",
                      time_budget_s=2.0).makespan_cycles
    assert (1 - a / m) >= 0.25


def test_depthwise_tiling_mostly_rejected():
    """Paper Table 2: DS-CNN/MobileNet see ~0% from tiling."""
    g = edge.ds_cnn()
    m = compile_model(g, SOC, PATS, mode="match",
                      time_budget_s=2.0).makespan_cycles
    a = compile_model(g, SOC, PATS, mode="matcha",
                      time_budget_s=2.0).makespan_cycles
    assert (1 - a / m) < 0.12
