"""Per-architecture smoke tests (deliverable f): reduced configs, one
forward/train step on CPU, output shapes + no NaNs, decode consistency."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.shapes import SHAPES, applicable
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.step import make_train_step

# excluded from the fast CI lane (-m "not slow")
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S):
    if cfg.input_kind == "tokens":
        return jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return jax.random.normal(KEY, (B, S, cfg.d_model), jnp.bfloat16)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = registry.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    B, S = 2, 32
    x = _inputs(cfg, B, S)
    logits = model.forward(cfg, params, x)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # remat path is numerically identical up to dtype noise
    lr = model.forward(cfg, params, x, remat=True)
    assert float(jnp.max(jnp.abs(logits - lr))) < 1e-2


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_one_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    B, S = 2, 16
    batch = {"x": _inputs(cfg, B, S),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    step = make_train_step(cfg, adamw.AdamWConfig(), remat=True)
    opt = adamw.init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert float(metrics["loss"]) > 0 and not jnp.isnan(metrics["loss"])
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", [a for a in registry.ARCH_IDS
                                  if registry.get_config(a).has_decode
                                  and registry.get_config(a).input_kind
                                  == "tokens"])
def test_decode_matches_forward(arch):
    """decode_step at position S equals forward on the extended sequence.
    For MoE archs the capacity factor is raised so no tokens drop — the
    train-time capacity dropping is otherwise (correctly) inconsistent
    with the drop-free decode path."""
    import repro.models.moe as moe_mod
    cfg = registry.get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    B, S = 2, 24
    x = _inputs(cfg, B, S)
    old_cap = moe_mod.CAPACITY_FACTOR
    if cfg.family == "moe":
        moe_mod.CAPACITY_FACTOR = float(cfg.n_experts)
    try:
        lg, cache = model.prefill(cfg, params, x, max_seq=S + 8)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        lg2, cache2 = model.decode_step(cfg, params, cache, tok)
        full = model.forward(cfg, params,
                             jnp.concatenate([x, tok[:, None]], 1))
    finally:
        moe_mod.CAPACITY_FACTOR = old_cap
    err = float(jnp.max(jnp.abs(full[:, S] - lg2)))
    assert err < 5e-2, err
    assert int(cache2["pos"][0]) == S + 1


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_shape_applicability_rules(arch):
    cfg = registry.get_config(arch)
    runs = {s: applicable(cfg, SHAPES[s])[0] for s in SHAPES}
    assert runs["train_4k"] and runs["prefill_32k"]
    if arch == "hubert-xlarge":
        assert not runs["decode_32k"] and not runs["long_500k"]
    if arch in ("rwkv6-3b", "recurrentgemma-2b", "gemma3-12b"):
        assert runs["long_500k"]
    if arch in ("qwen3-8b", "qwen3-32b", "internlm2-1.8b",
                "llava-next-mistral-7b", "olmoe-1b-7b",
                "granite-moe-3b-a800m"):
        assert not runs["long_500k"]


def test_live_cell_count():
    """10 train + 10 prefill + 9 decode + 3 long = 32 live cells."""
    from repro.configs.shapes import live_cells
    cfgs = [registry.get_config(a) for a in registry.ARCH_IDS]
    assert len(live_cells(cfgs)) == 32
