"""Multi-tenant co-scheduler: golden makespan, numerics regression vs. the
single-model oracle, and the serving engine on top of the co-schedule."""

import numpy as np
import pytest

from repro.core.api import compile_multi
from repro.core.memplan import validate_plan
from repro.core.runtime import (execute_multi_plan, execute_plan,
                                init_inputs, init_params,
                                multi_plan_matches_oracle)
from repro.core.schedule import validate_multi_schedule
from repro.models import edge
from repro.serve.engine import MultiModelEngine
from repro.soc.carfield import carfield_patterns, carfield_soc

SOC = carfield_soc()
PATS = carfield_patterns()

# fixed MLPerf-Tiny-style pair for the makespan golden test
GOLDEN_PAIR = ("autoencoder", "ds_cnn")


@pytest.fixture(scope="module")
def golden_mc():
    graphs = [edge.ALL_MODELS[m]() for m in GOLDEN_PAIR]
    return compile_multi(graphs, SOC, PATS, time_budget_s=1.0)


def test_coscheduled_makespan_beats_sequential(golden_mc):
    """Concurrency guard: re-tiled co-scheduled makespan <= PR-1
    co-scheduled makespan (compile-alone tilings) <= running each model
    alone back-to-back (the compile-each-model baseline)."""
    assert golden_mc.plan.makespan <= \
        golden_mc.baseline_makespan_cycles + 1e-6
    assert golden_mc.baseline_makespan_cycles <= \
        golden_mc.sequential_makespan_cycles + 1e-6
    assert golden_mc.speedup >= 1.0


def test_coschedule_is_feasible(golden_mc):
    assert validate_multi_schedule(golden_mc.plan) == []
    assert validate_plan(golden_mc.plan.memory) == []
    assert golden_mc.plan.memory.peak <= SOC.l2.size


def test_tenant_makespans_bounded(golden_mc):
    plan = golden_mc.plan
    for i in range(len(GOLDEN_PAIR)):
        assert 0.0 < plan.tenant_makespans[i] <= plan.makespan + 1e-6


def test_multi_numerics_matches_oracle(golden_mc):
    """Co-scheduled interleaved execution == per-model whole-graph oracle."""
    assert multi_plan_matches_oracle(golden_mc.plan)


def test_multi_numerics_bitmatch_single_plan(golden_mc):
    """Interleaving tenants must not perturb numerics at all: each tenant's
    outputs are bit-identical to executing a single-model plan over the
    same tiled graph alone (``tenant_plan`` — the compile-alone plan
    unless the tenant was contention-re-tiled)."""
    graphs = golden_mc.graphs
    params = [init_params(g, 2 * i) for i, g in enumerate(graphs)]
    inputs = [init_inputs(g, 2 * i + 1) for i, g in enumerate(graphs)]
    multi_out = execute_multi_plan(golden_mc.plan, inputs, params)
    for i, g in enumerate(graphs):
        single_out = execute_plan(golden_mc.tenant_plan(i), inputs[i],
                                  params[i])
        for t in g.outputs:
            assert np.array_equal(np.asarray(single_out[t]),
                                  np.asarray(multi_out[i][t])), (g.name, t)


def test_plan_for_partial_occupancy_no_fallback(golden_mc):
    """The session-backed artifact answers partial occupancy with a real
    validated co-schedule (the pre-PR-3 behaviour returned None and the
    engine fell back to compile-alone plans)."""
    for active in ([0], [1]):
        plan = golden_mc.plan_for(active)
        assert plan is not None
        assert validate_multi_schedule(plan) == []
        assert plan.makespan <= \
            golden_mc.tenant_plan(active[0]).makespan + 1e-6
    assert golden_mc.plan_for([0, 1]) is golden_mc.plan
    assert golden_mc.store_stats()["co_plans"] >= 1


def test_multi_engine_mixed_traffic(golden_mc):
    eng = MultiModelEngine(golden_mc)
    rids = [eng.submit("autoencoder"), eng.submit("ds_cnn"),
            eng.submit("autoencoder")]
    results = eng.run()
    assert set(results) == set(rids)
    rep = eng.report()
    assert rep["served"] == 3
    # 2 requests paired into one co-scheduled round, 1 solo leftover
    assert rep["co_rounds"] == 1
    assert rep["solo_dispatches"] == 1
    assert rep["throughput_inf_per_s"] > 0
    # co-scheduled requests report the tenant's co-schedule latency
    co = [r for r in eng.done.values() if r.co_scheduled]
    assert len(co) == 2
    for r in co:
        assert r.latency_ms == pytest.approx(
            golden_mc.tenant_latency_ms(r.tenant))


def test_multi_engine_output_correctness(golden_mc):
    """Engine-served outputs equal the direct single-plan execution for the
    same inputs and the engine's own parameters.  The solo dispatch path
    runs the tenant's reference schedule (``tenant_plan`` — identical to
    ``singles[0].plan`` unless the tenant was contention-re-tiled)."""
    eng = MultiModelEngine(golden_mc, seed=7)
    g0 = golden_mc.graphs[0]
    x = init_inputs(g0, 99)
    rid = eng.submit(0, inputs=x)
    eng.run()
    want = execute_plan(golden_mc.tenant_plan(0), x, eng.params[0])
    for t in g0.outputs:
        assert np.array_equal(np.asarray(want[t]),
                              np.asarray(eng.results[rid][t]))
