"""Hypothesis shim: use the real library when installed, else a tiny
deterministic stand-in so the property-based modules collect and run
everywhere (the seed suite failed collection wherever ``hypothesis`` was
missing).

The stand-in implements exactly the strategy surface these tests use —
``integers``, ``sampled_from``, ``lists``, ``tuples``, ``data`` — and a
``@given`` that replays a fixed-seed random draw for a bounded number of
examples (capped below ``max_examples`` to keep the fallback fast).  It is
NOT a shrinking property-testing engine; environments with pip should
``pip install -r requirements-dev.txt`` to get the real thing.
"""

from __future__ import annotations


import random

try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    _STANDIN_SEED = 0xA11CE
    _STANDIN_MAX = 10          # examples per test in the fallback engine

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    class _DataObject:
        """Stand-in for hypothesis's interactive ``data()`` object."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.draw(self._rng)

    class _St:
        """Namespace mirroring ``hypothesis.strategies``."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda r: items[r.randrange(len(items))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(r):
                n = r.randint(min_size, max_size)
                return [elements.draw(r) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda r: tuple(s.draw(r) for s in strategies))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.randrange(2)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def data():
            return _DataStrategy()

    st = _St()

    def settings(max_examples=None, **_ignored):
        """Records ``max_examples``; all other hypothesis knobs ignored."""
        def deco(fn):
            fn._standin_max_examples = max_examples
            return fn
        return deco

    def given(*garg_strategies, **gkw_strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                limit = getattr(wrapper, "_standin_max_examples", None) \
                    or getattr(fn, "_standin_max_examples", None) \
                    or _STANDIN_MAX
                limit = min(limit, _STANDIN_MAX)
                rng = random.Random(_STANDIN_SEED)
                for _ in range(limit):
                    drawn = [s.draw(rng) for s in garg_strategies]
                    drawn_kw = {k: s.draw(rng)
                                for k, s in gkw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)
            # NOT functools.wraps: copying __wrapped__ would expose the
            # strategy parameters to pytest's fixture resolution
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
