"""Multi-tenant serving: two different DNNs co-compiled onto ONE Carfield
SoC and served concurrently.

The single-model pipeline (see ``quickstart.py``) raises utilization by
running one model's tiles across all accelerators; ``compile_multi``
generalizes that to *inter-model* concurrency — N independent models share
the devices, the single system DMA (double-buffered planned loads), and
the 1 MiB L2 scratchpad (per-tenant budgets, contention-aware eviction).

    PYTHONPATH=src python examples/multi_tenant.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.api import compile_multi
from repro.core.runtime import multi_plan_matches_oracle
from repro.models import edge
from repro.serve.engine import MultiModelEngine
from repro.soc.carfield import carfield_patterns, carfield_soc


def main() -> None:
    soc = carfield_soc()
    patterns = carfield_patterns()
    graphs = [edge.autoencoder(), edge.ds_cnn()]

    print("co-compiling", " + ".join(g.name for g in graphs),
          "onto", soc.name, "...")
    mc = compile_multi(graphs, soc, patterns, time_budget_s=3.0)
    assert multi_plan_matches_oracle(mc.plan)   # co-exec == each alone

    print(f"\n{'model':14s} {'alone (ms)':>11s} {'co-scheduled (ms)':>18s}")
    for i, g in enumerate(graphs):
        alone = soc.cycles_to_ms(mc.singles[i].plan.makespan)
        print(f"{g.name:14s} {alone:11.2f} {mc.tenant_latency_ms(i):18.2f}")
    seq_ms = soc.cycles_to_ms(mc.sequential_makespan_cycles)
    pr1_ms = soc.cycles_to_ms(mc.baseline_makespan_cycles)
    print(f"\nround makespan: {seq_ms:.2f} ms sequential -> "
          f"{pr1_ms:.2f} ms co-scheduled -> "
          f"{mc.runtime_ms:.2f} ms contention-re-tiled "
          f"({mc.speedup:.2f}x, retiled={mc.retiled}, L2 budgets = "
          f"{[b // 1024 for b in mc.plan.budgets]} KiB)")
    util = mc.plan.utilization()
    print("utilization: " + "  ".join(f"{d}={u:.0%}"
                                      for d, u in sorted(util.items())))

    # serve a small mixed-tenant workload through the engine
    eng = MultiModelEngine(mc)
    for k in range(3):
        eng.submit("autoencoder")
        eng.submit("ds_cnn")
    eng.submit("autoencoder")           # one tenant deeper than the other
    eng.run()
    rep = eng.report()
    print(f"\nserved {rep['served']} requests: "
          f"{rep['co_rounds']} co-scheduled rounds + "
          f"{rep['solo_dispatches']} solo dispatches, "
          f"{rep['throughput_inf_per_s']:.1f} inf/s aggregate")
    for t in rep["per_tenant"]:
        print(f"  {t['model']:14s} served={t['served']}  "
              f"mean latency {t['mean_latency_ms']:.2f} ms")


if __name__ == "__main__":
    main()
