"""Multi-tenant serving through the deployment-session API: two DNNs
co-compiled onto ONE Carfield SoC and served concurrently at varying
occupancy.

A :class:`~repro.core.deploy.DeploymentSession` wraps the whole pipeline:
a typed ``CompileRequest`` (graphs, SoC, patterns, tile budgets) and a
typed ``Objective`` (makespan-primary, eviction-count tie-break) drive one
unified candidate search; the session then owns an occupancy-indexed
``PlanStore``, so ``plan_for(active)`` answers any subset of tenants with
a real co-schedule — the serving engine never falls back to compile-alone
plans when only some tenants have queued work.

Incremental re-solve
--------------------

Occupancy churn is usually *small*: tenants arrive and leave one at a
time, so the occupancy a miss lands on differs from some already-cached
occupancy by one member.  The session exploits that: every landed plan's
per-tenant tiling solutions go into a non-evicting sidecar of the
``PlanStore`` (a few integers per tenant — it survives LRU eviction of
the plan itself), and a ``plan_for`` miss warm-starts from the
Hamming-nearest cached occupancy (superset preferred: it co-tiled every
member under at least this much contention).  The warm start becomes
both a candidate tiling set *and* the joint CP's incumbent seed, so the
re-solve runs under the small ``incremental_time_budget_s`` instead of
the full from-scratch budget — on churny traces the miss compile-latency
p99 drops >= 2x (see ``benchmarks/multi_tenant.py``), while the
compile-alone concat floor still guarantees zero negative-gain rounds.
The shared L2 is re-split among the active tenants *proportionally to
their linearized working sets* (``l2_split="proportional"``), arbitrated
against the old equal split so the shipped plan is never worse.  The
demo below replays a churny trace and prints each miss's warm-start
source and compile wall time (``session.miss_events``).

Compile pipeline
----------------

Past ~10 tenants the monolithic joint CP stops converging inside its
time budget, and at fleet scale the occupancy lattice makes solve
*count* the bottleneck.  Three opt-in layers keep the compile pipeline
ahead of the request stream:

* **Decomposed joint solve** (``CompileRequest(decompose="auto")``,
  :mod:`repro.core.decompose`): tenants are clustered by dominant-device
  affinity (each fused region credited to the cheapest device offering
  it), oversized clusters split to ``decompose_max_cluster`` members,
  and the clusters solved concurrently under split L2/DMA budgets —
  then reconciled with Benders-style cuts from the exact stage-2
  ``schedule_multi`` evaluation (a cluster whose realized makespan
  exceeds its CP relaxation gets a bigger L2 slice and an overflow cut,
  iterated to a bounded fixpoint with an any-time incumbent).  The
  decomposed solutions enter candidate arbitration *alongside* the
  monolithic joint solve, so at equal total budget the session can
  never ship a worse plan — and wins outright once the monolithic
  solve stops converging (gated by ``check_regression --solve``).

* **Worker pool + occupancy-lattice prefetcher**
  (:class:`~repro.serve.compiler_thread.BackgroundCompiler` with
  ``max_workers``/``prefetch``): background miss compiles drain through
  a bounded priority pool (reactive misses always outrank speculation),
  while the prefetcher predicts likely next occupancies — Hamming-1
  neighbors of recently served occupancies plus external hints such as
  a fleet placement's per-SoC tenant sets — ranked by predicted request
  probability x staleness, so the next churn step's plan is usually
  compiled before it is requested.

* **Fleet-wide dedup**: every SoC hosting a class mix shares ONE
  ``BackgroundCompiler`` through the fleet's ``PlanCache``
  (``FleetConfig(async_compile=True)``), so an identical compile key
  queued or in flight anywhere in the rack bounces every other SoC's
  submit of the same key.

``MultiModelEngine.report()["solver"]`` exposes the per-session solver
telemetry (nodes, wall, budget exhaustion, incumbent sources, per-
context and decomposed tallies), and ``compile_latency_stats()`` splits
the latency percentiles by source (foreground/background/prefetch) so
speculative compiles cannot mask a foreground regression.

Serving & SLOs
--------------

Requests carry a priority class and an optional deadline, and the engine
grows three opt-in layers (all default-off; the bare engine stays FIFO):

    from repro.serve.admission import (AdmissionController, ClassPolicy,
                                       Priority, RoundComposer)

    eng = MultiModelEngine(
        mc,
        # bound best-effort queue depth; over-bound submits are rejected
        admission=AdmissionController({Priority.LOW:
                                       ClassPolicy(max_queued=8)}),
        # deadline-driven round composition: the occupancy dispatched
        # each round maximizes the predicted priority-weighted deadline
        # attainment (FIFO's all-active round wins all ties, starved
        # heads are force-included, feasible deadlines of deferred
        # tenants are protected)
        composer=RoundComposer(),
        # plan_for misses compile in the background (smaller
        # lazy_joint_time_budget_s); the round serves the compile-alone
        # concat floor instead of stalling on the joint CP solve
        async_compile=True,
        # drain up to 4 queued requests per tenant per round; repeated
        # waves of the same plan skip the parameter-load DMA traffic
        max_batch=4)

    eng.submit("kws", priority=Priority.HIGH, deadline_s=0.050)
    eng.submit("vision")                  # NORMAL, no deadline
    eng.run()
    eng.report()["per_class"]["HIGH"]     # attainment, p50/p99 e2e

``submit`` returns ``None`` for an admission-rejected request;
``report()`` adds per-class attainment/percentiles, round decomposition
(co / solo / fallback / floor rounds), starvation events (structurally 0)
and the admission/composer/background-compiler counters.

The legacy one-shot wrapper (``compile_multi``) is demoed at the end for
compat; it builds the same session internally.

Static plan analysis
--------------------

Every plan the session emits — the full house, each ``plan_for``
occupancy, the compile-alone references — passes through the static
plan analyzer (:mod:`repro.analysis`) before it lands in the
``PlanStore``.  The analyzer replays the schedule symbolically and
reports severity-graded diagnostics with stable rule ids:

    ===== ==================================================
    PA001 precedence: a node starts before a predecessor ends
    PA002 resource overlap: two kernels (or DMAs) share a
          device/DMA-engine window
    PA003 data hazard: a DMA moves a tensor while a kernel
          reads/writes it (RAW/WAR/WAW)
    PA004 use-after-evict: an access window not covered by an
          L2 residency rectangle
    PA005 aliasing: concurrently-live L2 allocations overlap
          in address space (or fall outside L2)
    PA006 tenant isolation: foreign owner in a namespace, or a
          tenant's static footprint over its budget slice
          (soft-budget peaks are WARNINGs)
    PA007 malformed DAG: cycles, unknown preds, unscheduled
          nodes
    PA008 double-buffer discipline: a DMA transfer with no
          backing L2 rectangle
    ===== ==================================================

``CompileRequest(analysis=...)`` picks the policy: ``"strict"`` (the
default) raises on any ERROR diagnostic so a hazardous plan can never
be cached or served, ``"warn"`` records diagnostics in
``session.analysis_stats()`` (surfaced under ``report()["analysis"]``
by the serving engine) but ships the plan, ``"off"`` skips analysis.
The legacy ``validate_schedule`` / ``validate_multi_schedule`` /
``validate_plan`` helpers are now thin shims over the same analyzer.

    PYTHONPATH=src python examples/multi_tenant.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.api import compile_multi
from repro.core.deploy import (CompileRequest, DeploymentSession, Objective)
from repro.core.runtime import multi_plan_matches_oracle
from repro.models import edge
from repro.serve.admission import Priority, RoundComposer
from repro.serve.engine import MultiModelEngine
from repro.soc.carfield import carfield_patterns, carfield_soc


def main() -> None:
    soc = carfield_soc()
    patterns = carfield_patterns()
    graphs = [edge.autoencoder(), edge.ds_cnn()]

    # -- the session API ----------------------------------------------------
    request = CompileRequest(graphs=graphs, soc=soc, patterns=patterns,
                             mode="matcha", time_budget_s=3.0)
    objective = Objective()            # makespan, evictions as tie-break
    session = DeploymentSession(request, objective)

    print("co-compiling", " + ".join(g.name for g in graphs),
          "onto", soc.name, "...")
    # pre-compile the useful partial occupancies alongside the full house
    mc = session.compile(precompile=[[0], [1]])
    assert multi_plan_matches_oracle(mc.plan)   # co-exec == each alone

    print(f"\n{'model':14s} {'alone (ms)':>11s} {'co-scheduled (ms)':>18s}")
    for i, g in enumerate(graphs):
        alone = soc.cycles_to_ms(mc.singles[i].plan.makespan)
        print(f"{g.name:14s} {alone:11.2f} {mc.tenant_latency_ms(i):18.2f}")
    seq_ms = soc.cycles_to_ms(mc.sequential_makespan_cycles)
    pr1_ms = soc.cycles_to_ms(mc.baseline_makespan_cycles)
    br_ms = soc.cycles_to_ms(mc.best_response_makespan_cycles)
    print(f"\nround makespan: {seq_ms:.2f} ms sequential -> "
          f"{pr1_ms:.2f} ms co-scheduled -> "
          f"{br_ms:.2f} ms best-response re-tiled -> "
          f"{mc.runtime_ms:.2f} ms joint "
          f"({mc.speedup:.2f}x, origin={mc.plan.origin}, "
          f"{session.hint_rounds} hint round(s), "
          f"joint={mc.joint_stats()}, L2 budgets = "
          f"{[b // 1024 for b in mc.plan.budgets]} KiB)")
    util = mc.plan.utilization()
    print("utilization: " + "  ".join(f"{d}={u:.0%}"
                                      for d, u in sorted(util.items())))

    # any occupancy gets a validated co-schedule from the plan store;
    # replaying a churny trace (tenants leaving/returning one at a time)
    # only compiles each occupancy once
    for active in ([0, 1], [0], [1], [0, 1], [0], [1]):
        plan = session.plan_for(active)
        names = " + ".join(graphs[i].name for i in active)
        print(f"plan_for({active}): {names:28s} "
              f"{soc.cycles_to_ms(plan.makespan):8.2f} ms")

    # incremental re-solve: each subset miss above warm-started from the
    # Hamming-nearest cached occupancy's tiling solutions (here the full
    # house — recorded in the plan store's non-evicting sidecar) instead
    # of re-tiling from scratch
    for ev in session.miss_events:
        print(f"miss {ev['occupancy']}: warm={ev['warm']} "
              f"neighbor={ev['neighbor']} origin={ev['origin']} "
              f"compiled in {ev['wall_s'] * 1e3:.0f} ms")
    lat = session.compile_latency_stats()
    print(f"miss compile latency: p50 {lat['p50_ms']:.0f} ms  "
          f"p99 {lat['p99_ms']:.0f} ms  "
          f"({lat['warm']['count']} warm / {lat['cold']['count']} cold; "
          f"L2 split wins: proportional {lat['prop_split_wins']}, "
          f"equal {lat['equal_split_wins']})")

    # serve a mixed-tenant workload; the uneven tail is a real (cached)
    # occupancy-1 dispatch, not a compile-alone fallback
    eng = MultiModelEngine(mc)
    for _ in range(3):
        eng.submit("autoencoder")
        eng.submit("ds_cnn")
    eng.submit("autoencoder")           # one tenant deeper than the other
    eng.run()
    rep = eng.report()
    print(f"\nserved {rep['served']} requests: "
          f"{rep['co_rounds']} co-scheduled rounds "
          f"({rep['subset_co_rounds']} at partial occupancy) + "
          f"{rep['solo_dispatches']} solo dispatches, "
          f"{rep['throughput_inf_per_s']:.1f} inf/s aggregate")
    for t in rep["per_tenant"]:
        print(f"  {t['model']:14s} served={t['served']}  "
              f"mean latency {t['mean_latency_ms']:.2f} ms")
    print(f"plan store: {rep['plan_store']}")
    ana = rep["analysis"]
    print(f"plan analysis ({ana['mode']}): {ana['plans_analyzed']} plans "
          f"analyzed, {ana['errors']} errors, "
          f"{ana['warnings']} warnings ({ana['by_rule'] or 'clean'})")

    # -- SLO-aware serving: priorities, deadlines, async compiles ----------
    # the autoencoder is latency-critical (HIGH, deadline between its
    # compile-alone latency and its co-scheduled completion); ds_cnn
    # submits a deadline-less backlog.  The deadline-driven composer
    # fast-paths the HIGH requests where FIFO would co-schedule them
    # behind the backlog.
    alone_s = soc.cycles_to_ms(mc.singles[0].plan.makespan) / 1e3
    co_s = soc.cycles_to_ms(mc.plan.tenant_makespans[0]) / 1e3
    deadline_s = 0.5 * (alone_s + co_s)
    slo = MultiModelEngine(mc, composer=RoundComposer(), execute=False)
    for _ in range(4):
        slo.submit("ds_cnn")
    for _ in range(3):
        slo.submit("autoencoder", priority=Priority.HIGH,
                   deadline_s=deadline_s)
    slo.run()
    srep = slo.report()
    high = srep["per_class"]["HIGH"]
    print(f"\nSLO serving: HIGH deadline {deadline_s * 1e3:.2f} ms -> "
          f"attainment {high['slo_attainment']:.0%} "
          f"(p99 e2e {high['p99_e2e_ms']:.2f} ms), "
          f"{srep['starvation_events']} starvation events, "
          f"composer {srep['composer']}")

    # -- legacy wrapper, still working ------------------------------------
    mc2 = compile_multi(graphs, soc, patterns, time_budget_s=3.0)
    print(f"\ncompile_multi wrapper: same winning makespan = "
          f"{mc2.runtime_ms:.2f} ms "
          f"(session-backed: {mc2.session is not None})")


if __name__ == "__main__":
    main()
