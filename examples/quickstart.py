"""Quickstart: compile a DNN for the Carfield heterogeneous SoC with the
four toolchains of the paper, validate the tiled plan numerically, inspect
the schedule, and emit the multi-ISA deployment artifact.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.api import compile_model
from repro.core.runtime import plan_matches_oracle
from repro.models import edge
from repro.soc.carfield import carfield_patterns, carfield_soc


def main() -> None:
    soc = carfield_soc()
    patterns = carfield_patterns()
    graph = edge.autoencoder()          # MLPerf-Tiny anomaly detection

    print(f"model: {graph.name}  "
          f"({graph.total_macs() / 1e6:.2f} M MACs, "
          f"{graph.total_params() / 1e3:.0f} k params)\n")

    results = {}
    for mode in ("tvm", "match", "matcha_nt", "matcha"):
        cm = compile_model(graph, soc, patterns, mode=mode,
                           time_budget_s=3.0)
        assert plan_matches_oracle(cm.plan)   # tiled exec == direct exec
        results[mode] = cm
        util = cm.plan.utilization()
        print(f"{mode:10s} {cm.runtime_ms:8.2f} ms   "
              f"util: " + "  ".join(f"{d}={u:.0%}"
                                    for d, u in util.items()
                                    if d != "dma"))

    m, a = results["match"], results["matcha"]
    print(f"\nMATCHA vs MATCH: "
          f"{100 * (1 - a.makespan_cycles / m.makespan_cycles):.1f}% "
          f"latency reduction (paper: 33.3%)")

    out = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "quickstart_deploy")
    files = a.emit(out)
    print(f"\nemitted {len(files)} deployment files to {out}/:")
    for f in sorted(files):
        print(f"  {f}")


if __name__ == "__main__":
    main()
