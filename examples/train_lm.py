"""End-to-end training driver: train a (reduced) assigned architecture for
a few hundred steps on the synthetic pipeline, with checkpoint/restart via
the fault supervisor — the same driver that runs pod-scale configs.

    PYTHONPATH=src python examples/train_lm.py [--arch internlm2-1.8b]
    PYTHONPATH=src python examples/train_lm.py --steps 300   # ~100M-class
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        # finite corpus (documents repeat) so the synthetic stream has
        # learnable statistics
        out = train(args.arch, steps=args.steps, batch=args.batch,
                    seq=args.seq, smoke=True, ckpt_dir=ckpt,
                    ckpt_every=max(args.steps // 4, 10), num_docs=48)
        losses = out["losses"]
        k = max(len(losses) // 8, 1)
        first, last = (sum(losses[:k]) / k, sum(losses[-k:]) / k)
        print(f"\n{args.arch}: loss {first:.3f} -> {last:.3f} "
              f"over {len(losses)} steps")
        assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
