"""Fleet-scale serving: place tenants across a rack of SoCs, route an
open-loop trace, kill a SoC mid-trace, and watch the fleet re-host its
tenants without dropping a request.

MATCHA co-schedules N tenants on ONE multi-accelerator SoC; the fleet
layer (``repro.fleet``) asks the level-up question: given a rack of
identical SoCs, which co-residency sets should exist at all, which SoC
serves each request, and what happens when a SoC dies.

The three layers, in the order this demo exercises them:

``placement``
    :func:`~repro.fleet.place_contention_aware` chooses the co-residency
    sets.  Edge weights come from measured pair contention — the
    :class:`~repro.fleet.ContentionModel` compiles each pair's joint
    plan and scores the makespan excess over the heavier member alone.
    The objective is *bottleneck utilization under balanced demand*
    (:func:`~repro.fleet.balanced_utilization`): the analytic mirror of
    the engines' co-scheduled rounds, minimized by a greedy seed, a CP
    polish (the ``meshplan`` coverage/capacity constraint shape with
    SoCs as devices and tenants as tiles), and move/swap local search.

``router``
    :class:`~repro.fleet.FleetRouter` dispatches each request to the
    accepting host with the lowest *round-structured* completion
    estimate (own-queue depth x joint-round cost, plus the round
    dilation the request inflicts on queued co-residents), warm cached
    plans attracting traffic.  The placement hands the router its
    ``demand_split`` — the per-SoC demand shares whose bottleneck
    utilization the placement optimized — and the router paces dispatch
    toward those shares.

``rebalance``
    :class:`~repro.fleet.FleetRebalancer` handles drain/failure: queued
    work on a dead SoC is drained and requeued through the router with
    absolute deadlines preserved, orphaned classes re-host on the
    surviving SoC that dilutes capacity least (cache-hit rebind, or a
    fresh compile warm-started from the solutions sidecars donated by
    the dead SoC's session), and per-event recovery latency is measured
    in the same shape as the training supervisor's ``RunReport``.

Run:  PYTHONPATH=src python examples/fleet.py
"""

from repro.fleet import (ContentionModel, FailureEvent, Fleet, FleetConfig,
                         FleetRebalancer, FleetRouter, PlanCache,
                         place_contention_aware, replay_open_loop)
from repro.models import edge
from repro.serve.admission import Priority
from repro.soc.carfield import carfield_patterns, carfield_soc

CLASSES = ("autoencoder", "ds_cnn", "mobilenet", "resnet")


def main() -> None:
    config = FleetConfig(
        soc_factory=lambda: (carfield_soc(), carfield_patterns()),
        n_socs=4, capacity=2, requested_tiles=8,
        time_budget_s=0.5, joint_time_budget_s=1.0,
        lazy_joint_time_budget_s=0.5, incremental_time_budget_s=0.5)
    graphs = [edge.ALL_MODELS[m]() for m in CLASSES]
    cache = PlanCache(config, graphs)
    contention = ContentionModel(cache)

    # -- placement: one replica of each class over 4 SoCs ------------------
    placement = place_contention_aware(list(CLASSES), config.n_socs,
                                       config.capacity, contention)
    print("measured pair contention (round excess over heavier alone):")
    for pair, stats in contention.edges().items():
        print(f"  {pair:26s} excess {stats['excess_s'] * 1e3:7.3f} ms   "
              f"slowdown {stats['slowdown']:.2f}x")
    print(f"\ncontention-aware placement (max rho "
          f"{placement.max_rho:.3f}):")
    for soc_id, names in enumerate(placement.assignment):
        print(f"  soc{soc_id}: {' + '.join(names) if names else '(spare)'}")

    # -- route an open-loop trace, killing a SoC halfway -------------------
    fleet = Fleet(config, graphs, cache=cache, contention=contention)
    fleet.apply_placement(placement)
    router = FleetRouter(fleet, split=placement.demand_split)
    rebalancer = FleetRebalancer(fleet, router)

    high = "mobilenet"                    # deadline-carrying class
    deadline_s = 2.5 * contention.alone_s(high)
    trace = []
    for c in CLASSES:
        period = 3.0 * contention.alone_s(c)      # ~1/3 utilization each
        t = 0.4 * period
        while t < 8.0:
            trace.append((t, c, Priority.HIGH if c == high
                          else Priority.NORMAL,
                          deadline_s if c == high else None))
            t += period
    victim = fleet.hosts_of(high)[0].soc_id
    t_fail = 4.0
    print(f"\nreplaying {len(trace)} requests over 8s; "
          f"SoC {victim} (hosting {high}) dies at t={t_fail:.1f}s ...")
    summary = replay_open_loop(
        fleet, router, trace,
        failures=[FailureEvent(at_s=t_fail, soc_id=victim, kind="fail")],
        rebalancer=rebalancer)

    # -- what happened -----------------------------------------------------
    audit = summary["router"]
    print(f"\nserved {summary['served']}, dropped {audit['dropped']}, "
          f"requeued {audit['requeued']} "
          f"(warm routes {audit['warm_routes']}, cold "
          f"{audit['cold_routes']})")
    att = summary["per_class"]["HIGH"]["slo_attainment"]
    print(f"HIGH-class deadline attainment: "
          f"{'-' if att is None else format(att, '.1%')}")
    for m in rebalancer.stats()["records"]:
        how = ("cache-hit rebind" if m["cache_hit"] else
               f"fresh compile, {m['seeded_occupancies']} sidecar "
               f"occupancies seeded")
        print(f"migration: {m['class_name']} soc{m['src_soc']} -> "
              f"soc{m['dst_soc']} at t={m['at_s']:.2f}s ({how}, "
              f"recovery {m['recovery_s'] * 1e3:.1f} ms, analyzer "
              f"errors {m['analyzer_errors']})")
    print(f"fleet makespan: {fleet.makespan_s():.3f} s")


if __name__ == "__main__":
    main()
