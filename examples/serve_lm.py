"""Co-scheduled LM + vision serving walkthrough.

    PYTHONPATH=src python examples/serve_lm.py [--lm rwkv6]

What this demonstrates, step by step:

1.  **One engine, two kinds of tenant.**  A fixed-shape vision-style
    graph and a shape-bucketed LM tenant (``lm_tenant`` pairs the LM's
    default prefill graph with a ``ShapeBucketSpec`` — power-of-two
    sequence buckets from 1, the decode shape, up to ``max_seq``) are
    compiled into one ``DeploymentSession``.  There is no separate
    token-loop engine for the LM: prefill and decode are ordinary
    bucketed requests to the same ``MultiModelEngine``.

2.  **Prefill, then decode, through the same queue.**  A prompt of
    length L submits as ``submit(lm, seq_len=L)`` — the spec rounds L up
    to its bucket — and each generated token submits as
    ``submit(lm, seq_len=1)``.  The engine resolves every round's plan
    at the ``(occupancy, bucket-vector)`` lattice point of the queued
    heads, so a decode round co-schedules with the vision tenant under a
    plan priced for seq=1, not for the prefill shape.

3.  **The bucket-transition prefetch.**  The attached
    ``BackgroundCompiler`` (deterministic no-thread mode here) watches
    dispatched lattice points and walks one Hamming step along the
    lattice — occupancy joins/leaves and one-rung bucket ladder moves,
    with the step toward seq=1 weighted double.  After the first prefill
    round it is already compiling the decode-bucket plan, so the
    prefill->decode transition lands on a warm plan instead of a floor
    round.

Run with ``--no-prefetch`` to watch the same trace pay floor rounds at
every bucket transition instead.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lm", default="rwkv6",
                    choices=["rwkv6", "rglru", "transformer"])
    ap.add_argument("--prompts", type=int, default=3)
    ap.add_argument("--decode-steps", type=int, default=6)
    ap.add_argument("--no-prefetch", action="store_true")
    args = ap.parse_args()
    rep = serve(args.lm, n_prompts=args.prompts,
                decode_steps=args.decode_steps,
                prefetch=not args.no_prefetch)
    print(f"  starvation events: {rep['starvation_events']}, "
          f"slo attainment: {rep['slo_attainment']}")


if __name__ == "__main__":
    main()
