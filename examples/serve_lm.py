"""Batched serving example: continuous-batching engine over prefill/decode
with greedy and temperature sampling.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-8b]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()
    results = serve(args.arch, n_requests=args.requests, max_new=12)
    for rid, toks in sorted(results.items()):
        print(f"  request {rid}: {toks}")


if __name__ == "__main__":
    main()
