"""Device extension example (Table 1 "Device Extension"): define a custom
heterogeneous SoC — host + a systolic GEMM NPU + a SIMD DSP — with its own
kernel pattern catalogue, and compile a transformer block for it.

    PYTHONPATH=src python examples/custom_soc.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.api import compile_model
from repro.core.patterns import chain, wildcard
from repro.core.runtime import plan_matches_oracle
from repro.models import edge
from repro.soc.device import Device, MemoryLevel, SoC

KiB = 1024
MiB = 1024 * KiB


def my_soc() -> SoC:
    host = Device("cpu", alpha=1.5,
                  l1=MemoryLevel("cpu_l1", 64 * KiB, 8.0),
                  dma_bandwidth=8.0, is_host=True, copy_bandwidth=0.5)
    npu = Device("npu", alpha=0.1,           # systolic GEMM engine
                 l1=MemoryLevel("npu_l1", 512 * KiB, 32.0),
                 dma_bandwidth=16.0)
    dsp = Device("dsp", alpha=0.8,           # SIMD vector DSP
                 l1=MemoryLevel("dsp_l1", 128 * KiB, 16.0),
                 dma_bandwidth=8.0)
    return SoC(name="my_soc", devices={"cpu": host, "npu": npu,
                                       "dsp": dsp},
               l2=MemoryLevel("l2", 2 * MiB, 32.0),
               l3=MemoryLevel("l3", 256 * MiB, 8.0),
               dma_l3_bandwidth=8.0, mailbox_latency=150.0, freq_mhz=200.0)


def my_patterns():
    ps = []
    # NPU: GEMM-class ops only, very efficient, high invocation cost
    for ops_, eta in [(["dense"], 0.85), (["dense", "bias_add"], 0.85),
                      (["matmul"], 0.85), (["batch_matmul"], 0.80),
                      (["conv2d"], 0.75),
                      (["conv2d", "bias_add", "relu"], 0.75)]:
        ps.append(chain("npu", "npu_" + "_".join(ops_), ops_, eta, 4000.0))
    # DSP: elementwise/activations + small convs
    for ops_, eta in [(["add"], 0.7), (["add", "relu"], 0.7),
                      (["dwconv2d"], 0.6),
                      (["dense"], 0.35), (["softmax"], 0.5)]:
        ps.append(chain("dsp", "dsp_" + "_".join(ops_), ops_, eta, 800.0))
    ps.append(wildcard("cpu", eta=0.3, delta=200.0))
    return ps


def main() -> None:
    soc, pats = my_soc(), my_patterns()
    g = edge.transformer_block()
    for mode in ("match", "matcha"):
        cm = compile_model(g, soc, pats, mode=mode, time_budget_s=3.0)
        assert plan_matches_oracle(cm.plan)
        print(f"{mode:8s} {cm.makespan_cycles / 1e3:9.1f}k cycles  "
              f"util={ {d: f'{u:.0%}' for d, u in cm.plan.utilization().items()} }")


if __name__ == "__main__":
    main()
